//! Task scheduling policies.
//!
//! The scheduler answers one question, asked every time a node goes idle:
//! *what should this node work on next?* The paper's contribution is the
//! **locality** policy (grid-brick: run where the data lives, §4); the
//! baselines it argues against / alongside are implemented too so the
//! benches can compare them (DESIGN.md Ext-C/Ext-D):
//!
//! - [`locality`]: grid-brick — each brick is processed by a node that
//!   holds a replica; zero raw-data movement.
//! - [`central`]: the traditional Globus/DataGrid pattern (§3) — all data
//!   sits on the central server and is staged to whichever node is free.
//! - [`proof`]: PROOF-style master/worker adaptive packets (§2) — event
//!   ranges handed out pull-style, sized to each worker's measured rate,
//!   reprocessed elsewhere on worker failure.
//! - [`gfarm`]: Gfarm-style (§2) — affinity to fragment holders with idle
//!   work-stealing (a transfer makes the steal explicit).
//! - [`balanced`]: the paper's §7 "submit more work to the best nodes" —
//!   locality first, then cost-based migration of queued bricks from slow
//!   to fast nodes when the transfer pays for itself.
//!
//! All policies implement the pull-based [`Scheduler`] trait, which both
//! the discrete-event simulator (`sim::scenario`) and the live tokio
//! cluster (`cluster`) drive — the *same decision code* produces Fig 7 and
//! the real runs.

pub mod balanced;
pub mod central;
pub mod gfarm;
pub mod locality;
pub mod proof;

use crate::brick::BrickId;
use std::collections::BTreeMap;

/// What the scheduler knows about a node.
#[derive(Debug, Clone)]
pub struct NodeState {
    pub name: String,
    /// relative CPU speed (events/s multiplier; 1.0 = reference)
    pub speed: f64,
    /// concurrent task slots (GRAM job-manager slots)
    pub slots: usize,
    pub up: bool,
}

/// What the scheduler knows about a brick.
#[derive(Debug, Clone)]
pub struct BrickState {
    pub id: BrickId,
    pub n_events: usize,
    pub bytes: u64,
    /// replica holders, primary first
    pub holders: Vec<String>,
}

/// A unit of work handed to a node.
#[derive(Debug, Clone, PartialEq)]
pub struct Task {
    pub brick: BrickId,
    /// event sub-range within the brick [start, end) — whole brick unless
    /// the policy packetises (PROOF)
    pub range: (usize, usize),
    /// where the raw data must be read from; None = local disk
    pub source: Option<String>,
}

impl Task {
    pub fn n_events(&self) -> usize {
        self.range.1 - self.range.0
    }
}

/// Immutable context handed to the scheduler on each pull.
#[derive(Debug, Clone)]
pub struct SchedCtx {
    pub nodes: Vec<NodeState>,
    pub bricks: Vec<BrickState>,
    /// name of the central data host (leader) for `central` staging
    pub leader: String,
}

impl SchedCtx {
    pub fn node(&self, name: &str) -> Option<&NodeState> {
        self.nodes.iter().find(|n| n.name == name)
    }

    pub fn brick(&self, id: BrickId) -> Option<&BrickState> {
        // bricks are generated in id order (split_events); binary search
        // keeps scheduler pulls O(log n) instead of O(n) per task (§Perf)
        match self.bricks.binary_search_by(|b| b.id.cmp(&id)) {
            Ok(idx) => Some(&self.bricks[idx]),
            Err(_) => self.bricks.iter().find(|b| b.id == id),
        }
    }

    pub fn live_nodes(&self) -> impl Iterator<Item = &NodeState> {
        self.nodes.iter().filter(|n| n.up)
    }

    /// Mark `name` down in this context. Returns true only when the
    /// node was present and up — i.e. this call made the transition —
    /// so callers (the per-job runners fed by the shared JSE event
    /// loop) can run their failover path exactly once per node death.
    pub fn mark_down(&mut self, name: &str) -> bool {
        match self.nodes.iter_mut().find(|n| n.name == name) {
            Some(n) if n.up => {
                n.up = false;
                true
            }
            _ => false,
        }
    }

    /// Elastic membership: fold a newly joined node into this context
    /// mid-job so the policy can start offering it work. Returns false
    /// (and changes nothing) if a node of that name already exists —
    /// a name is never recycled within one job.
    pub fn add_node(&mut self, node: NodeState) -> bool {
        if self.nodes.iter().any(|n| n.name == node.name) {
            return false;
        }
        self.nodes.push(node);
        true
    }
}

/// Pull-based scheduling policy. Implementations own their queue state.
pub trait Scheduler: Send {
    /// Node `node` is idle; hand it a task (or None if nothing suits it).
    fn next_task(&mut self, node: &str, ctx: &SchedCtx) -> Option<Task>;

    /// `node` finished `task` successfully, processing `n` events in
    /// `elapsed` seconds (rate feedback for adaptive policies).
    fn on_complete(&mut self, node: &str, task: &Task, elapsed: f64);

    /// `node` failed (or died) while running `task`; the work must be
    /// re-issued elsewhere.
    fn on_failure(&mut self, node: &str, task: &Task, ctx: &SchedCtx);

    /// `node` went down entirely: requeue all its pending affinity work.
    fn on_node_down(&mut self, node: &str, ctx: &SchedCtx);

    /// A node joined the grid mid-job (elastic membership). The default
    /// is a no-op: pull-based policies see the newcomer the moment the
    /// event loop starts offering its idle slots through `next_task`
    /// with the updated context, so most need no queue surgery.
    fn on_node_up(&mut self, _node: &str, _ctx: &SchedCtx) {}

    /// The telemetry health engine re-classified `node` (healthy ⇄
    /// degraded/unhealthy). Advisory: the JSE already orders its idle-slot
    /// offers healthy-first, so the default is a no-op; adaptive policies
    /// may additionally shrink packet sizes or steer queued affinity work
    /// away from a sick node.
    fn on_health(&mut self, _node: &str, _healthy: bool, _ctx: &SchedCtx) {}

    /// All work assigned AND completed.
    fn is_done(&self) -> bool;

    /// Human-readable policy name (reports/benches).
    fn name(&self) -> &'static str;
}

/// Which policy to instantiate (config / CLI surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Locality,
    Central,
    Proof,
    Gfarm,
    Balanced,
}

impl Policy {
    pub fn by_name(s: &str) -> Option<Policy> {
        match s {
            "locality" | "grid-brick" => Some(Policy::Locality),
            "central" | "traditional" => Some(Policy::Central),
            "proof" => Some(Policy::Proof),
            "gfarm" => Some(Policy::Gfarm),
            "balanced" => Some(Policy::Balanced),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::Locality => "locality",
            Policy::Central => "central",
            Policy::Proof => "proof",
            Policy::Gfarm => "gfarm",
            Policy::Balanced => "balanced",
        }
    }

    /// Instantiate the policy over the brick set.
    pub fn build(self, ctx: &SchedCtx) -> Box<dyn Scheduler> {
        match self {
            Policy::Locality => Box::new(locality::Locality::new(ctx)),
            Policy::Central => Box::new(central::Central::new(ctx)),
            Policy::Proof => Box::new(proof::Proof::new(ctx)),
            Policy::Gfarm => Box::new(gfarm::Gfarm::new(ctx)),
            Policy::Balanced => Box::new(balanced::Balanced::new(ctx)),
        }
    }

    pub const ALL: [Policy; 5] = [
        Policy::Locality,
        Policy::Central,
        Policy::Proof,
        Policy::Gfarm,
        Policy::Balanced,
    ];
}

/// Shared bookkeeping used by several policies: outstanding (issued but
/// not completed) tasks per node, completed event count.
#[derive(Debug, Default)]
pub struct Progress {
    pub outstanding: BTreeMap<String, Vec<Task>>,
    pub completed_events: usize,
    pub completed_tasks: usize,
}

impl Progress {
    pub fn issue(&mut self, node: &str, task: Task) -> Task {
        self.outstanding
            .entry(node.to_string())
            .or_default()
            .push(task.clone());
        task
    }

    pub fn complete(&mut self, node: &str, task: &Task) {
        if let Some(v) = self.outstanding.get_mut(node) {
            if let Some(pos) = v.iter().position(|t| t == task) {
                v.remove(pos);
            }
        }
        self.completed_events += task.n_events();
        self.completed_tasks += 1;
    }

    /// Remove and return everything outstanding on `node`.
    pub fn drain_node(&mut self, node: &str) -> Vec<Task> {
        self.outstanding.remove(node).unwrap_or_default()
    }

    pub fn outstanding_count(&self) -> usize {
        self.outstanding.values().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn ctx2() -> SchedCtx {
        // the paper's testbed: gandalf + hobbit, bricks spread across both
        SchedCtx {
            nodes: vec![
                NodeState {
                    name: "gandalf".into(),
                    speed: 0.8,
                    slots: 1,
                    up: true,
                },
                NodeState {
                    name: "hobbit".into(),
                    speed: 1.0,
                    slots: 1,
                    up: true,
                },
            ],
            bricks: (0..4)
                .map(|i| BrickState {
                    id: BrickId::new(1, i),
                    n_events: 500,
                    bytes: 500 << 20,
                    holders: vec![if i % 2 == 0 {
                        "gandalf".into()
                    } else {
                        "hobbit".into()
                    }],
                })
                .collect(),
            leader: "jse".into(),
        }
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::by_name(p.name()), Some(p));
        }
        assert_eq!(Policy::by_name("grid-brick"), Some(Policy::Locality));
        assert_eq!(Policy::by_name("bogus"), None);
    }

    #[test]
    fn mark_down_transitions_once() {
        let mut ctx = ctx2();
        assert!(ctx.mark_down("gandalf"));
        assert!(!ctx.mark_down("gandalf"), "second call is a no-op");
        assert!(!ctx.mark_down("mordor"), "unknown node is a no-op");
        assert!(!ctx.node("gandalf").unwrap().up);
        assert_eq!(ctx.live_nodes().count(), 1);
    }

    #[test]
    fn add_node_joins_once_and_feeds_stealing_policies() {
        let mut ctx = ctx2();
        let newcomer = NodeState {
            name: "rohan".into(),
            speed: 1.0,
            slots: 1,
            up: true,
        };
        assert!(ctx.add_node(newcomer.clone()));
        assert!(!ctx.add_node(newcomer), "names are never recycled");
        assert_eq!(ctx.live_nodes().count(), 3);
        // a gfarm scheduler built before the join hands the newcomer
        // stolen work once the context knows about it
        let base = ctx2();
        let mut s = Policy::Gfarm.build(&base);
        let mut joined = base.clone();
        assert!(s.next_task("rohan", &joined).is_none(), "not a member yet");
        joined.add_node(NodeState {
            name: "rohan".into(),
            speed: 1.0,
            slots: 1,
            up: true,
        });
        s.on_node_up("rohan", &joined);
        let t = s.next_task("rohan", &joined);
        // ctx2 holds 2 bricks per node; the newcomer steals one
        assert!(t.is_some(), "joined node must be offered work");
        assert!(t.unwrap().source.is_some(), "stolen work pays a transfer");
    }

    #[test]
    fn progress_bookkeeping() {
        let mut p = Progress::default();
        let t = Task {
            brick: BrickId::new(1, 0),
            range: (0, 100),
            source: None,
        };
        p.issue("a", t.clone());
        assert_eq!(p.outstanding_count(), 1);
        p.complete("a", &t);
        assert_eq!(p.outstanding_count(), 0);
        assert_eq!(p.completed_events, 100);
    }

    #[test]
    fn drain_node_returns_outstanding() {
        let mut p = Progress::default();
        let t1 = Task {
            brick: BrickId::new(1, 0),
            range: (0, 10),
            source: None,
        };
        let t2 = Task {
            brick: BrickId::new(1, 1),
            range: (0, 20),
            source: None,
        };
        p.issue("a", t1);
        p.issue("a", t2);
        assert_eq!(p.drain_node("a").len(), 2);
        assert_eq!(p.outstanding_count(), 0);
    }

    /// Generic conformance: every policy must process all events exactly
    /// once on a healthy cluster, regardless of pull order.
    #[test]
    fn all_policies_cover_all_events() {
        for policy in Policy::ALL {
            let ctx = ctx2();
            let mut s = policy.build(&ctx);
            let total: usize = ctx.bricks.iter().map(|b| b.n_events).sum();
            let mut processed = 0usize;
            let mut guard = 0;
            'outer: loop {
                let mut any = false;
                for node in ["gandalf", "hobbit"] {
                    if let Some(t) = s.next_task(node, &ctx) {
                        processed += t.n_events();
                        s.on_complete(node, &t, 1.0);
                        any = true;
                    }
                }
                guard += 1;
                if s.is_done() {
                    break 'outer;
                }
                assert!(any, "{}: stalled before done", s.name());
                assert!(guard < 10_000, "{}: runaway", s.name());
            }
            assert_eq!(processed, total, "{}", s.name());
        }
    }
}
