//! PROOF-style adaptive packet scheduling (paper §2).
//!
//! The master hands out *packets* — event sub-ranges of bricks — pull
//! style. Packet size adapts to each worker's measured rate so that
//! "slower slave servers get smaller data packets than faster slave
//! servers", targeting a fixed packet wall-time. The master "keeps a list
//! of all generated packets per slave, so in case a slave failed then
//! remaining slaves can reprocess its packets".
//!
//! Data affinity: a packet's raw bytes are read from the brick's replica
//! holder; workers that hold the brick read locally, others pull remotely
//! (source = holder), matching PROOF's TChain remote-access behaviour.

use crate::brick::BrickId;
use crate::scheduler::{Progress, SchedCtx, Scheduler, Task};
use std::collections::{BTreeMap, VecDeque};

/// Target wall-clock seconds per packet (PROOF uses ~ a few seconds).
const TARGET_PACKET_S: f64 = 4.0;
/// Bounds on packet size in events.
const MIN_PACKET: usize = 16;
const MAX_PACKET: usize = 4096;
/// Initial assumed rate (events/s) before any feedback.
const INITIAL_RATE: f64 = 50.0;

struct BrickCursor {
    id: BrickId,
    n_events: usize,
    next: usize,
}

pub struct Proof {
    /// bricks with unassigned event ranges
    cursors: VecDeque<BrickCursor>,
    /// measured events/s per worker (EWMA)
    rates: BTreeMap<String, f64>,
    progress: Progress,
    total_events: usize,
    /// events requeued from failures, as explicit (brick, range) packets
    requeued: VecDeque<(BrickId, (usize, usize))>,
}

impl Proof {
    pub fn new(ctx: &SchedCtx) -> Self {
        Proof {
            cursors: ctx
                .bricks
                .iter()
                .map(|b| BrickCursor { id: b.id, n_events: b.n_events, next: 0 })
                .collect(),
            rates: BTreeMap::new(),
            progress: Progress::default(),
            total_events: ctx.bricks.iter().map(|b| b.n_events).sum(),
            requeued: VecDeque::new(),
        }
    }

    fn packet_events(&self, node: &str) -> usize {
        let rate = self.rates.get(node).copied().unwrap_or(INITIAL_RATE);
        ((rate * TARGET_PACKET_S) as usize).clamp(MIN_PACKET, MAX_PACKET)
    }

    fn source_for(&self, brick: BrickId, node: &str, ctx: &SchedCtx) -> Option<String> {
        let holders = &ctx.brick(brick)?.holders;
        if holders.iter().any(|h| h == node) {
            None // local read
        } else {
            // remote read from the first live holder, else the leader
            holders
                .iter()
                .find(|h| ctx.node(h).map(|n| n.up).unwrap_or(false))
                .cloned()
                .or(Some(ctx.leader.clone()))
        }
    }

    /// Current measured rate for a node (exposed for tests/reports).
    pub fn rate(&self, node: &str) -> Option<f64> {
        self.rates.get(node).copied()
    }
}

impl Scheduler for Proof {
    fn next_task(&mut self, node: &str, ctx: &SchedCtx) -> Option<Task> {
        if !ctx.node(node).map(|n| n.up).unwrap_or(false) {
            return None;
        }
        let want = self.packet_events(node);

        // failed packets first (reprocessing)
        if let Some((brick, range)) = self.requeued.pop_front() {
            let source = self.source_for(brick, node, ctx);
            return Some(self.progress.issue(node, Task { brick, range, source }));
        }

        // otherwise carve the next packet off the current brick cursor
        let cur = self.cursors.front_mut()?;
        let start = cur.next;
        let end = (start + want).min(cur.n_events);
        cur.next = end;
        let brick = cur.id;
        if cur.next >= cur.n_events {
            self.cursors.pop_front();
        }
        let source = self.source_for(brick, node, ctx);
        Some(self.progress.issue(node, Task { brick, range: (start, end), source }))
    }

    fn on_complete(&mut self, node: &str, task: &Task, elapsed: f64) {
        self.progress.complete(node, task);
        if elapsed > 0.0 {
            let observed = task.n_events() as f64 / elapsed;
            let prev = self.rates.get(node).copied().unwrap_or(observed);
            // EWMA, alpha = 0.5 (PROOF reacts fast)
            self.rates.insert(node.to_string(), 0.5 * prev + 0.5 * observed);
        }
    }

    fn on_failure(&mut self, node: &str, task: &Task, _ctx: &SchedCtx) {
        if let Some(v) = self.progress.outstanding.get_mut(node) {
            v.retain(|t| t != task);
        }
        self.requeued.push_back((task.brick, task.range));
    }

    fn on_node_down(&mut self, node: &str, _ctx: &SchedCtx) {
        for t in self.progress.drain_node(node) {
            self.requeued.push_back((t.brick, t.range));
        }
        self.rates.remove(node);
    }

    fn is_done(&self) -> bool {
        self.cursors.is_empty()
            && self.requeued.is_empty()
            && self.progress.outstanding_count() == 0
            && self.progress.completed_events >= self.total_events
    }

    fn name(&self) -> &'static str {
        "proof"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{BrickState, NodeState};

    fn ctx() -> SchedCtx {
        SchedCtx {
            nodes: vec![
                NodeState { name: "fast".into(), speed: 2.0, slots: 1, up: true },
                NodeState { name: "slow".into(), speed: 0.5, slots: 1, up: true },
            ],
            bricks: vec![BrickState {
                id: BrickId::new(1, 0),
                n_events: 100_000,
                bytes: 100_000 << 10,
                holders: vec!["fast".into()],
            }],
            leader: "jse".into(),
        }
    }

    #[test]
    fn packets_adapt_to_measured_rate() {
        let c = ctx();
        let mut s = Proof::new(&c);
        // feed rate observations: fast node does 1000 ev/s, slow 25 ev/s
        let t = s.next_task("fast", &c).unwrap();
        s.on_complete("fast", &t, t.n_events() as f64 / 1000.0);
        let t = s.next_task("slow", &c).unwrap();
        s.on_complete("slow", &t, t.n_events() as f64 / 25.0);
        // next packets reflect the rates (one more round to converge EWMA)
        let tf = s.next_task("fast", &c).unwrap();
        let ts = s.next_task("slow", &c).unwrap();
        assert!(
            tf.n_events() > 3 * ts.n_events(),
            "fast {} slow {}",
            tf.n_events(),
            ts.n_events()
        );
        assert!(ts.n_events() >= MIN_PACKET);
        assert!(tf.n_events() <= MAX_PACKET);
    }

    #[test]
    fn packets_partition_the_brick() {
        let c = ctx();
        let mut s = Proof::new(&c);
        let mut covered = vec![false; 100_000];
        loop {
            let mut any = false;
            for n in ["fast", "slow"] {
                if let Some(t) = s.next_task(n, &c) {
                    for i in t.range.0..t.range.1 {
                        assert!(!covered[i], "event {i} double-assigned");
                        covered[i] = true;
                    }
                    s.on_complete(n, &t, 0.5);
                    any = true;
                }
            }
            if s.is_done() {
                break;
            }
            assert!(any, "stalled");
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn failed_packets_reprocessed_elsewhere() {
        let mut c = ctx();
        let mut s = Proof::new(&c);
        let t = s.next_task("slow", &c).unwrap();
        c.nodes[1].up = false;
        s.on_failure("slow", &t, &c);
        s.on_node_down("slow", &c);
        // the failed range must be re-issued to the surviving node
        let mut got_range = false;
        while let Some(t2) = s.next_task("fast", &c) {
            if t2.brick == t.brick && t2.range == t.range {
                got_range = true;
            }
            s.on_complete("fast", &t2, 0.1);
        }
        assert!(got_range);
        assert!(s.is_done());
    }

    #[test]
    fn remote_readers_get_a_source() {
        let c = ctx();
        let mut s = Proof::new(&c);
        let t = s.next_task("slow", &c).unwrap(); // slow doesn't hold d1.b0
        assert_eq!(t.source.as_deref(), Some("fast"));
        let t2 = s.next_task("fast", &c).unwrap(); // fast holds it
        assert_eq!(t2.source, None);
    }
}
