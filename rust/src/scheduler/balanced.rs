//! Speed-aware load balancing — the paper's §7 future work, "develop a
//! storage mechanism to submit more work to the best nodes", built as a
//! first-class policy.
//!
//! Strategy: locality first (a node always prefers its own bricks). When a
//! node runs dry it may take a *remote* brick from the node whose queue
//! will take the longest to drain **per unit of speed** — i.e. we migrate
//! work away from slow, backlogged nodes — but only when the estimated
//! benefit (queue-drain time saved) exceeds the transfer cost estimate.

use crate::brick::BrickId;
use crate::scheduler::{Progress, SchedCtx, Scheduler, Task};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Rough LAN staging rate used in the migrate-or-not estimate
/// (bytes/sec). The decision only needs the right order of magnitude; the
/// DES/netsim charges the *actual* modelled cost.
const EST_TRANSFER_BPS: f64 = 12_500_000.0;
/// Rough per-event compute seconds at speed 1.0 for the estimate.
const EST_EVENT_S: f64 = 0.05;

pub struct Balanced {
    queues: BTreeMap<String, VecDeque<BrickId>>,
    progress: Progress,
    total_tasks: usize,
    completed_or_lost: usize,
    lost: BTreeSet<BrickId>,
}

impl Balanced {
    pub fn new(ctx: &SchedCtx) -> Self {
        let mut queues: BTreeMap<String, VecDeque<BrickId>> = BTreeMap::new();
        for b in &ctx.bricks {
            let primary = b.holders.first().expect("brick with no holders");
            queues.entry(primary.clone()).or_default().push_back(b.id);
        }
        Balanced {
            queues,
            progress: Progress::default(),
            total_tasks: ctx.bricks.len(),
            completed_or_lost: 0,
            lost: BTreeSet::new(),
        }
    }

    /// Estimated seconds for `node` to drain its remaining queue.
    fn drain_estimate(&self, node: &str, ctx: &SchedCtx) -> f64 {
        let speed = ctx.node(node).map(|n| n.speed).unwrap_or(1.0).max(0.01);
        let events: usize = self
            .queues
            .get(node)
            .map(|q| {
                q.iter()
                    .filter_map(|b| ctx.brick(*b))
                    .map(|b| b.n_events)
                    .sum()
            })
            .unwrap_or(0);
        events as f64 * EST_EVENT_S / speed
    }
}

impl Scheduler for Balanced {
    fn next_task(&mut self, node: &str, ctx: &SchedCtx) -> Option<Task> {
        if !ctx.node(node).map(|n| n.up).unwrap_or(false) {
            return None;
        }
        // 1) local brick
        if let Some(q) = self.queues.get_mut(node) {
            if let Some(brick) = q.pop_front() {
                let n_events =
                    ctx.brick(brick).map(|b| b.n_events).unwrap_or(0);
                return Some(self.progress.issue(
                    node,
                    Task { brick, range: (0, n_events), source: None },
                ));
            }
        }
        // 2) migrate from the most backlogged (time-wise) victim if the
        //    transfer pays for itself
        let my_speed = ctx.node(node).map(|n| n.speed).unwrap_or(1.0).max(0.01);
        let victim = self
            .queues
            .iter()
            .filter(|(n, q)| n.as_str() != node && !q.is_empty())
            .map(|(n, _)| (self.drain_estimate(n, ctx), n.clone()))
            .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())?;
        let (victim_drain, victim_name) = victim;

        let brick = *self.queues[&victim_name].back()?;
        let bs = ctx.brick(brick)?;
        let transfer_s = bs.bytes as f64 / EST_TRANSFER_BPS;
        let my_compute = bs.n_events as f64 * EST_EVENT_S / my_speed;
        let victim_speed =
            ctx.node(&victim_name).map(|n| n.speed).unwrap_or(1.0).max(0.01);
        let victim_compute = bs.n_events as f64 * EST_EVENT_S / victim_speed;
        // benefit: the victim's tail shortens by its compute time; cost:
        // we spend transfer + compute. Migrate when we'd finish this brick
        // before the victim would even reach it.
        let reach_time = victim_drain - victim_compute;
        if transfer_s + my_compute < reach_time + victim_compute {
            let brick = self.queues.get_mut(&victim_name)?.pop_back()?;
            let n_events = bs.n_events;
            return Some(self.progress.issue(
                node,
                Task {
                    brick,
                    range: (0, n_events),
                    source: Some(victim_name),
                },
            ));
        }
        None
    }

    fn on_complete(&mut self, node: &str, task: &Task, _elapsed: f64) {
        self.progress.complete(node, task);
        self.completed_or_lost += 1;
    }

    fn on_failure(&mut self, node: &str, task: &Task, ctx: &SchedCtx) {
        if let Some(v) = self.progress.outstanding.get_mut(node) {
            v.retain(|t| t != task);
        }
        let holders = ctx
            .brick(task.brick)
            .map(|b| b.holders.clone())
            .unwrap_or_default();
        if let Some(h) = holders
            .iter()
            .find(|h| ctx.node(h).map(|n| n.up).unwrap_or(false))
        {
            self.queues.entry(h.clone()).or_default().push_back(task.brick);
        } else if self.lost.insert(task.brick) {
            self.completed_or_lost += 1;
        }
    }

    fn on_node_down(&mut self, node: &str, ctx: &SchedCtx) {
        let queued: Vec<BrickId> = self
            .queues
            .remove(node)
            .map(|q| q.into_iter().collect())
            .unwrap_or_default();
        let inflight: Vec<BrickId> = self
            .progress
            .drain_node(node)
            .into_iter()
            .map(|t| t.brick)
            .collect();
        for brick in queued.into_iter().chain(inflight) {
            let holders = ctx
                .brick(brick)
                .map(|b| b.holders.clone())
                .unwrap_or_default();
            if let Some(h) = holders.iter().find(|h| {
                *h != node && ctx.node(h).map(|n| n.up).unwrap_or(false)
            }) {
                self.queues.entry(h.clone()).or_default().push_back(brick);
            } else if self.lost.insert(brick) {
                self.completed_or_lost += 1;
            }
        }
    }

    fn is_done(&self) -> bool {
        self.completed_or_lost == self.total_tasks
            && self.progress.outstanding_count() == 0
    }

    fn name(&self) -> &'static str {
        "balanced"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{BrickState, NodeState};

    fn ctx_hetero() -> SchedCtx {
        // slow node holds 8 bricks, fast node holds none
        SchedCtx {
            nodes: vec![
                NodeState {
                    name: "slow".into(),
                    speed: 0.25,
                    slots: 1,
                    up: true,
                },
                NodeState {
                    name: "fast".into(),
                    speed: 2.0,
                    slots: 1,
                    up: true,
                },
            ],
            bricks: (0..8)
                .map(|i| BrickState {
                    id: BrickId::new(1, i),
                    n_events: 2000,
                    bytes: 64 << 20,
                    holders: vec!["slow".into()],
                })
                .collect(),
            leader: "jse".into(),
        }
    }

    #[test]
    fn fast_node_takes_remote_work_from_backlogged_slow_node() {
        let c = ctx_hetero();
        let mut s = Balanced::new(&c);
        let t = s.next_task("fast", &c).unwrap();
        assert_eq!(t.source.as_deref(), Some("slow"));
    }

    #[test]
    fn local_work_preferred() {
        let c = ctx_hetero();
        let mut s = Balanced::new(&c);
        let t = s.next_task("slow", &c).unwrap();
        assert_eq!(t.source, None);
    }

    #[test]
    fn no_pointless_migration_when_queues_are_short() {
        // one small brick on slow: fast shouldn't steal (transfer doesn't pay)
        let mut c = ctx_hetero();
        c.bricks.truncate(1);
        c.bricks[0].n_events = 10;
        c.bricks[0].bytes = 1 << 30; // huge transfer, tiny compute
        let mut s = Balanced::new(&c);
        assert!(s.next_task("fast", &c).is_none());
    }

    #[test]
    fn everything_completes() {
        let c = ctx_hetero();
        let mut s = Balanced::new(&c);
        let mut seen = BTreeSet::new();
        loop {
            let mut any = false;
            for n in ["slow", "fast"] {
                if let Some(t) = s.next_task(n, &c) {
                    assert!(seen.insert(t.brick));
                    s.on_complete(n, &t, 1.0);
                    any = true;
                }
            }
            if s.is_done() {
                break;
            }
            assert!(any, "stalled with {} done", seen.len());
        }
        assert_eq!(seen.len(), 8);
    }
}
