//! Gfarm-style scheduling (paper §2): jobs are "redistributed to nodes
//! which contain the fragment database files" — affinity to fragment
//! holders, like locality — but an idle node with no local fragments left
//! may *steal* a fragment from the most-loaded holder, paying the
//! transfer explicitly. This models Gfarm's file-affinity scheduling with
//! its replication-based load spreading.

use crate::brick::BrickId;
use crate::scheduler::{Progress, SchedCtx, Scheduler, Task};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

pub struct Gfarm {
    queues: BTreeMap<String, VecDeque<BrickId>>,
    progress: Progress,
    total_tasks: usize,
    completed_or_lost: usize,
    lost: BTreeSet<BrickId>,
}

impl Gfarm {
    pub fn new(ctx: &SchedCtx) -> Self {
        let mut queues: BTreeMap<String, VecDeque<BrickId>> = BTreeMap::new();
        for b in &ctx.bricks {
            let primary = b.holders.first().expect("brick with no holders");
            queues.entry(primary.clone()).or_default().push_back(b.id);
        }
        Gfarm {
            queues,
            progress: Progress::default(),
            total_tasks: ctx.bricks.len(),
            completed_or_lost: 0,
            lost: BTreeSet::new(),
        }
    }

    /// The node with the longest remaining local queue (steal victim).
    fn most_loaded(&self) -> Option<&String> {
        self.queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .max_by_key(|(n, q)| (q.len(), std::cmp::Reverse(n.as_str())))
            .map(|(n, _)| n)
    }
}

impl Scheduler for Gfarm {
    fn next_task(&mut self, node: &str, ctx: &SchedCtx) -> Option<Task> {
        if !ctx.node(node).map(|n| n.up).unwrap_or(false) {
            return None;
        }
        // local fragment first
        if let Some(q) = self.queues.get_mut(node) {
            if let Some(brick) = q.pop_front() {
                let n_events =
                    ctx.brick(brick).map(|b| b.n_events).unwrap_or(0);
                return Some(self.progress.issue(
                    node,
                    Task { brick, range: (0, n_events), source: None },
                ));
            }
        }
        // idle: steal from the most loaded holder, only if it has > 1
        // queued (stealing its last brick rarely pays)
        let victim = self.most_loaded()?.clone();
        if victim == node || self.queues[&victim].len() <= 1 {
            return None;
        }
        let brick = self.queues.get_mut(&victim)?.pop_back()?;
        let n_events = ctx.brick(brick).map(|b| b.n_events).unwrap_or(0);
        Some(self.progress.issue(
            node,
            Task { brick, range: (0, n_events), source: Some(victim) },
        ))
    }

    fn on_complete(&mut self, node: &str, task: &Task, _elapsed: f64) {
        self.progress.complete(node, task);
        self.completed_or_lost += 1;
    }

    fn on_failure(&mut self, node: &str, task: &Task, ctx: &SchedCtx) {
        if let Some(v) = self.progress.outstanding.get_mut(node) {
            v.retain(|t| t != task);
        }
        // requeue at any live replica holder
        let holders = ctx
            .brick(task.brick)
            .map(|b| b.holders.clone())
            .unwrap_or_default();
        if let Some(h) = holders
            .iter()
            .find(|h| ctx.node(h).map(|n| n.up).unwrap_or(false))
        {
            self.queues.entry(h.clone()).or_default().push_back(task.brick);
        } else if self.lost.insert(task.brick) {
            self.completed_or_lost += 1;
        }
    }

    fn on_node_down(&mut self, node: &str, ctx: &SchedCtx) {
        let queued: Vec<BrickId> = self
            .queues
            .remove(node)
            .map(|q| q.into_iter().collect())
            .unwrap_or_default();
        let inflight: Vec<BrickId> = self
            .progress
            .drain_node(node)
            .into_iter()
            .map(|t| t.brick)
            .collect();
        for brick in queued.into_iter().chain(inflight) {
            let holders = ctx
                .brick(brick)
                .map(|b| b.holders.clone())
                .unwrap_or_default();
            if let Some(h) = holders.iter().find(|h| {
                *h != node && ctx.node(h).map(|n| n.up).unwrap_or(false)
            }) {
                self.queues.entry(h.clone()).or_default().push_back(brick);
            } else if self.lost.insert(brick) {
                self.completed_or_lost += 1;
            }
        }
    }

    fn is_done(&self) -> bool {
        self.completed_or_lost == self.total_tasks
            && self.progress.outstanding_count() == 0
    }

    fn name(&self) -> &'static str {
        "gfarm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{BrickState, NodeState};

    fn ctx_skewed() -> SchedCtx {
        // all 6 bricks on node a; node b idle
        SchedCtx {
            nodes: vec![
                NodeState { name: "a".into(), speed: 1.0, slots: 1, up: true },
                NodeState { name: "b".into(), speed: 1.0, slots: 1, up: true },
            ],
            bricks: (0..6)
                .map(|i| BrickState {
                    id: BrickId::new(1, i),
                    n_events: 10,
                    bytes: 100,
                    holders: vec!["a".into()],
                })
                .collect(),
            leader: "jse".into(),
        }
    }

    #[test]
    fn local_work_has_no_source() {
        let c = ctx_skewed();
        let mut s = Gfarm::new(&c);
        let t = s.next_task("a", &c).unwrap();
        assert_eq!(t.source, None);
    }

    #[test]
    fn idle_node_steals_with_transfer() {
        let c = ctx_skewed();
        let mut s = Gfarm::new(&c);
        let t = s.next_task("b", &c).unwrap();
        assert_eq!(t.source.as_deref(), Some("a"));
    }

    #[test]
    fn steal_leaves_last_brick_alone() {
        let c = SchedCtx {
            bricks: c_bricks(1),
            ..ctx_skewed()
        };
        let mut s = Gfarm::new(&c);
        assert!(s.next_task("b", &c).is_none());
        assert!(s.next_task("a", &c).is_some());
    }

    fn c_bricks(n: u32) -> Vec<BrickState> {
        (0..n)
            .map(|i| BrickState {
                id: BrickId::new(1, i),
                n_events: 10,
                bytes: 100,
                holders: vec!["a".into()],
            })
            .collect()
    }

    #[test]
    fn all_bricks_processed_once() {
        let c = ctx_skewed();
        let mut s = Gfarm::new(&c);
        let mut seen = BTreeSet::new();
        loop {
            let mut any = false;
            for n in ["a", "b"] {
                if let Some(t) = s.next_task(n, &c) {
                    assert!(seen.insert(t.brick), "duplicate {:?}", t.brick);
                    s.on_complete(n, &t, 1.0);
                    any = true;
                }
            }
            if s.is_done() {
                break;
            }
            assert!(any);
        }
        assert_eq!(seen.len(), 6);
    }

    #[test]
    fn holder_death_without_replica_loses_brick() {
        let mut c = ctx_skewed();
        c.nodes[0].up = false;
        let mut s = Gfarm::new(&c);
        s.on_node_down("a", &c);
        assert!(s.is_done());
        assert_eq!(s.lost.len(), 6);
    }
}
