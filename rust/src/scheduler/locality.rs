//! Grid-brick locality scheduling — the paper's contribution (§4).
//!
//! Every brick is queued at the nodes that hold a replica; a node pulling
//! work receives one of *its own* bricks, so raw data never crosses the
//! network. If a node dies, its bricks fail over to surviving replica
//! holders; bricks whose replicas are all dead are reported lost by
//! `is_done` staying false and `lost()` listing them (the paper's
//! "biggest disadvantage ... in the case of failure of one of the nodes").

use crate::brick::BrickId;
use crate::scheduler::{Progress, SchedCtx, Scheduler, Task};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

pub struct Locality {
    /// per-node FIFO of bricks local to it
    queues: BTreeMap<String, VecDeque<BrickId>>,
    /// brick -> remaining replica holders not yet tried
    fallbacks: BTreeMap<BrickId, Vec<String>>,
    progress: Progress,
    total_tasks: usize,
    lost: BTreeSet<BrickId>,
}

impl Locality {
    pub fn new(ctx: &SchedCtx) -> Self {
        let mut queues: BTreeMap<String, VecDeque<BrickId>> = BTreeMap::new();
        let mut fallbacks = BTreeMap::new();
        for b in &ctx.bricks {
            let primary = b
                .holders
                .first()
                .expect("brick with no holders")
                .clone();
            queues.entry(primary).or_default().push_back(b.id);
            fallbacks.insert(b.id, b.holders[1..].to_vec());
        }
        Locality {
            queues,
            fallbacks,
            progress: Progress::default(),
            total_tasks: ctx.bricks.len(),
            lost: BTreeSet::new(),
        }
    }

    /// Bricks that can no longer be processed anywhere.
    pub fn lost(&self) -> &BTreeSet<BrickId> {
        &self.lost
    }

    fn requeue(&mut self, brick: BrickId, ctx: &SchedCtx) {
        let fb = self.fallbacks.entry(brick).or_default();
        while let Some(next) = fb.pop() {
            let alive = ctx.node(&next).map(|n| n.up).unwrap_or(false);
            if alive {
                self.queues.entry(next).or_default().push_back(brick);
                return;
            }
        }
        self.lost.insert(brick);
    }
}

impl Scheduler for Locality {
    fn next_task(&mut self, node: &str, _ctx: &SchedCtx) -> Option<Task> {
        let q = self.queues.get_mut(node)?;
        let brick = q.pop_front()?;
        let n_events = _ctx.brick(brick).map(|b| b.n_events).unwrap_or(0);
        Some(self.progress.issue(
            node,
            Task { brick, range: (0, n_events), source: None },
        ))
    }

    fn on_complete(&mut self, node: &str, task: &Task, _elapsed: f64) {
        self.progress.complete(node, task);
    }

    fn on_failure(&mut self, node: &str, task: &Task, ctx: &SchedCtx) {
        if let Some(v) = self.progress.outstanding.get_mut(node) {
            v.retain(|t| t != task);
        }
        self.requeue(task.brick, ctx);
    }

    fn on_node_down(&mut self, node: &str, ctx: &SchedCtx) {
        // requeue queued-but-unissued bricks
        let queued: Vec<BrickId> = self
            .queues
            .remove(node)
            .map(|q| q.into_iter().collect())
            .unwrap_or_default();
        for b in queued {
            self.requeue(b, ctx);
        }
        // requeue in-flight bricks
        for t in self.progress.drain_node(node) {
            self.requeue(t.brick, ctx);
        }
    }

    fn is_done(&self) -> bool {
        self.progress.completed_tasks + self.lost.len() == self.total_tasks
            && self.progress.outstanding_count() == 0
    }

    fn name(&self) -> &'static str {
        "locality"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{BrickState, NodeState};

    fn ctx() -> SchedCtx {
        SchedCtx {
            nodes: vec![
                NodeState { name: "a".into(), speed: 1.0, slots: 1, up: true },
                NodeState { name: "b".into(), speed: 1.0, slots: 1, up: true },
            ],
            bricks: vec![
                BrickState {
                    id: BrickId::new(1, 0),
                    n_events: 100,
                    bytes: 1000,
                    holders: vec!["a".into(), "b".into()],
                },
                BrickState {
                    id: BrickId::new(1, 1),
                    n_events: 200,
                    bytes: 2000,
                    holders: vec!["b".into(), "a".into()],
                },
            ],
            leader: "jse".into(),
        }
    }

    #[test]
    fn tasks_are_strictly_local() {
        let c = ctx();
        let mut s = Locality::new(&c);
        let ta = s.next_task("a", &c).unwrap();
        assert_eq!(ta.brick, BrickId::new(1, 0));
        assert_eq!(ta.source, None);
        let tb = s.next_task("b", &c).unwrap();
        assert_eq!(tb.brick, BrickId::new(1, 1));
        assert!(s.next_task("a", &c).is_none());
    }

    #[test]
    fn failover_to_replica() {
        let mut c = ctx();
        let mut s = Locality::new(&c);
        let ta = s.next_task("a", &c).unwrap();
        // node a dies mid-task
        c.nodes[0].up = false;
        s.on_failure("a", &ta, &c);
        s.on_node_down("a", &c);
        // b picks up both its own brick and a's failed-over brick
        let t1 = s.next_task("b", &c).unwrap();
        let t2 = s.next_task("b", &c).unwrap();
        let mut ids = vec![t1.brick, t2.brick];
        ids.sort();
        assert_eq!(ids, vec![BrickId::new(1, 0), BrickId::new(1, 1)]);
        s.on_complete("b", &t1, 1.0);
        s.on_complete("b", &t2, 1.0);
        assert!(s.is_done());
        assert!(s.lost().is_empty());
    }

    #[test]
    fn unreplicated_brick_is_lost_when_holder_dies() {
        let mut c = ctx();
        c.bricks[0].holders = vec!["a".into()]; // replication = 1
        let mut s = Locality::new(&c);
        c.nodes[0].up = false;
        s.on_node_down("a", &c);
        assert_eq!(s.lost().len(), 1);
        let t = s.next_task("b", &c).unwrap();
        s.on_complete("b", &t, 1.0);
        assert!(s.is_done()); // done, with one lost brick reported
    }

    #[test]
    fn completion_accounting() {
        let c = ctx();
        let mut s = Locality::new(&c);
        assert!(!s.is_done());
        let ta = s.next_task("a", &c).unwrap();
        let tb = s.next_task("b", &c).unwrap();
        s.on_complete("a", &ta, 2.0);
        assert!(!s.is_done());
        s.on_complete("b", &tb, 2.0);
        assert!(s.is_done());
    }
}
