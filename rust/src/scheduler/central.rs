//! Traditional central-server scheduling — the baseline the paper argues
//! against (§3): all raw data lives on the central data server (the
//! leader); any free node can take any brick, but every brick must first
//! be staged over the network from the leader. The leader's NIC becomes
//! the shared bottleneck, which is exactly what Ext-D measures.

use crate::scheduler::{Progress, SchedCtx, Scheduler, Task};
use std::collections::VecDeque;

pub struct Central {
    queue: VecDeque<Task>,
    progress: Progress,
    total_tasks: usize,
}

impl Central {
    pub fn new(ctx: &SchedCtx) -> Self {
        let queue: VecDeque<Task> = ctx
            .bricks
            .iter()
            .map(|b| Task {
                brick: b.id,
                range: (0, b.n_events),
                source: Some(ctx.leader.clone()),
            })
            .collect();
        Central { total_tasks: queue.len(), queue, progress: Progress::default() }
    }
}

impl Scheduler for Central {
    fn next_task(&mut self, node: &str, ctx: &SchedCtx) -> Option<Task> {
        if !ctx.node(node).map(|n| n.up).unwrap_or(false) {
            return None;
        }
        let task = self.queue.pop_front()?;
        Some(self.progress.issue(node, task))
    }

    fn on_complete(&mut self, node: &str, task: &Task, _elapsed: f64) {
        self.progress.complete(node, task);
    }

    fn on_failure(&mut self, node: &str, task: &Task, _ctx: &SchedCtx) {
        if let Some(v) = self.progress.outstanding.get_mut(node) {
            v.retain(|t| t != task);
        }
        // central server still has the data: simply requeue
        self.queue.push_back(task.clone());
    }

    fn on_node_down(&mut self, node: &str, _ctx: &SchedCtx) {
        for t in self.progress.drain_node(node) {
            self.queue.push_back(t);
        }
    }

    fn is_done(&self) -> bool {
        self.queue.is_empty()
            && self.progress.outstanding_count() == 0
            && self.progress.completed_tasks == self.total_tasks
    }

    fn name(&self) -> &'static str {
        "central"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brick::BrickId;
    use crate::scheduler::{BrickState, NodeState};

    fn ctx() -> SchedCtx {
        SchedCtx {
            nodes: vec![
                NodeState { name: "a".into(), speed: 1.0, slots: 1, up: true },
                NodeState { name: "b".into(), speed: 1.0, slots: 1, up: true },
            ],
            bricks: (0..3)
                .map(|i| BrickState {
                    id: BrickId::new(1, i),
                    n_events: 10,
                    bytes: 100,
                    holders: vec!["a".into()], // ignored by central
                })
                .collect(),
            leader: "datacenter".into(),
        }
    }

    #[test]
    fn every_task_stages_from_leader() {
        let c = ctx();
        let mut s = Central::new(&c);
        while let Some(t) = s.next_task("a", &c) {
            assert_eq!(t.source.as_deref(), Some("datacenter"));
            s.on_complete("a", &t, 1.0);
        }
        assert!(s.is_done());
    }

    #[test]
    fn any_node_can_take_any_brick() {
        let c = ctx();
        let mut s = Central::new(&c);
        let t1 = s.next_task("b", &c).unwrap();
        let t2 = s.next_task("a", &c).unwrap();
        assert_ne!(t1.brick, t2.brick);
    }

    #[test]
    fn failure_requeues() {
        let c = ctx();
        let mut s = Central::new(&c);
        let t = s.next_task("a", &c).unwrap();
        s.on_failure("a", &t, &c);
        // the same brick is eventually reissued
        let mut seen = Vec::new();
        while let Some(t2) = s.next_task("b", &c) {
            seen.push(t2.brick);
            s.on_complete("b", &t2, 1.0);
        }
        assert!(seen.contains(&t.brick));
        assert!(s.is_done());
    }

    #[test]
    fn down_node_gets_nothing() {
        let mut c = ctx();
        c.nodes[0].up = false;
        let mut s = Central::new(&c);
        assert!(s.next_task("a", &c).is_none());
        assert!(s.next_task("b", &c).is_some());
    }
}
