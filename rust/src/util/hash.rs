//! xxHash64 — fast non-cryptographic hashing for brick-page checksums and
//! consistent placement. Implemented from the public spec; vectors checked
//! against the reference implementation in tests.

const PRIME1: u64 = 0x9E3779B185EBCA87;
const PRIME2: u64 = 0xC2B2AE3D27D4EB4F;
const PRIME3: u64 = 0x165667B19E3779F9;
const PRIME4: u64 = 0x85EBCA77C2B2AE63;
const PRIME5: u64 = 0x27D4EB2F165667C5;

#[inline]
fn read_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

#[inline]
fn read_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes(b[..4].try_into().unwrap())
}

#[inline]
fn round(acc: u64, input: u64) -> u64 {
    acc.wrapping_add(input.wrapping_mul(PRIME2))
        .rotate_left(31)
        .wrapping_mul(PRIME1)
}

#[inline]
fn merge_round(acc: u64, val: u64) -> u64 {
    (acc ^ round(0, val)).wrapping_mul(PRIME1).wrapping_add(PRIME4)
}

/// xxHash64 of `data` with `seed`.
pub fn xxhash64(data: &[u8], seed: u64) -> u64 {
    let len = data.len();
    let mut h: u64;
    let mut rest = data;

    if len >= 32 {
        let mut v1 = seed.wrapping_add(PRIME1).wrapping_add(PRIME2);
        let mut v2 = seed.wrapping_add(PRIME2);
        let mut v3 = seed;
        let mut v4 = seed.wrapping_sub(PRIME1);
        while rest.len() >= 32 {
            v1 = round(v1, read_u64(&rest[0..]));
            v2 = round(v2, read_u64(&rest[8..]));
            v3 = round(v3, read_u64(&rest[16..]));
            v4 = round(v4, read_u64(&rest[24..]));
            rest = &rest[32..];
        }
        h = v1
            .rotate_left(1)
            .wrapping_add(v2.rotate_left(7))
            .wrapping_add(v3.rotate_left(12))
            .wrapping_add(v4.rotate_left(18));
        h = merge_round(h, v1);
        h = merge_round(h, v2);
        h = merge_round(h, v3);
        h = merge_round(h, v4);
    } else {
        h = seed.wrapping_add(PRIME5);
    }

    h = h.wrapping_add(len as u64);

    while rest.len() >= 8 {
        h = (h ^ round(0, read_u64(rest)))
            .rotate_left(27)
            .wrapping_mul(PRIME1)
            .wrapping_add(PRIME4);
        rest = &rest[8..];
    }
    if rest.len() >= 4 {
        h = (h ^ (read_u32(rest) as u64).wrapping_mul(PRIME1))
            .rotate_left(23)
            .wrapping_mul(PRIME2)
            .wrapping_add(PRIME3);
        rest = &rest[4..];
    }
    for &b in rest {
        h = (h ^ (b as u64).wrapping_mul(PRIME5))
            .rotate_left(11)
            .wrapping_mul(PRIME1);
    }

    h ^= h >> 33;
    h = h.wrapping_mul(PRIME2);
    h ^= h >> 29;
    h = h.wrapping_mul(PRIME3);
    h ^= h >> 32;
    h
}

/// Stable hash of a string id (for consistent brick placement).
pub fn hash_str(s: &str, seed: u64) -> u64 {
    xxhash64(s.as_bytes(), seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Known-answer vectors from the xxHash reference implementation.
    #[test]
    fn known_vectors() {
        assert_eq!(xxhash64(b"", 0), 0xEF46DB3751D8E999);
        assert_eq!(xxhash64(b"a", 0), 0xD24EC4F1A98C6E5B);
        assert_eq!(xxhash64(b"abc", 0), 0x44BC2CF5AD770999);
        assert_eq!(
            xxhash64(b"Nobody inspects the spammish repetition", 0),
            0xFBCEA83C8A378BF1
        );
    }

    #[test]
    fn seed_changes_hash() {
        assert_ne!(xxhash64(b"geps", 0), xxhash64(b"geps", 1));
    }

    #[test]
    fn long_input_all_paths() {
        // >32 bytes exercises the vector loop + all tail paths.
        let data: Vec<u8> = (0..=255u8).collect();
        let h1 = xxhash64(&data, 0);
        let h2 = xxhash64(&data[..255], 0);
        assert_ne!(h1, h2);
        for tail in 0..9 {
            let _ = xxhash64(&data[..32 + tail], 7);
        }
    }

    #[test]
    fn single_bit_flip_avalanche() {
        let mut data = vec![0u8; 64];
        let h0 = xxhash64(&data, 0);
        data[40] ^= 1;
        let h1 = xxhash64(&data, 0);
        assert!((h0 ^ h1).count_ones() > 16);
    }
}
