//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! Every stochastic component in GEPS (event generator, failure injection,
//! workload sweeps) takes an explicit seed so experiments are exactly
//! replayable — the paper's 130-execution protocol (§6) relies on repeated
//! runs being comparable.

/// xoshiro256** PRNG (Blackman & Vigna), seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Different seeds give independent
    /// streams; the same seed gives an identical stream on every platform.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-node / per-job rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [lo, hi) (hi exclusive; lo < hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo < hi);
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (caches nothing; two uniforms/call).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate (mean = 1/rate). Used for Poisson
    /// arrival processes in the workload generator.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Poisson-distributed count (Knuth's method; fine for small lambda).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // guard against pathological lambda
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let m: f64 =
            (0..n).map(|_| r.poisson(3.0) as f64).sum::<f64>() / n as f64;
        assert!((m - 3.0).abs() < 0.1, "mean {m}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(21);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.range_u64(10, 20);
            assert!((10..20).contains(&x));
            let i = r.index(7);
            assert!(i < 7);
        }
    }
}
