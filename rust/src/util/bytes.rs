//! Byte-size arithmetic and human-readable formatting.
//!
//! The paper measures everything in "events of ~1 MB"; we keep byte
//! accounting explicit so transfer times out of `netsim` are auditable.

use std::fmt;
use std::ops::{Add, AddAssign, Mul, Sub};

pub const KB: u64 = 1 << 10;
pub const MB: u64 = 1 << 20;
pub const GB: u64 = 1 << 30;

/// A byte count with helpers for rate math.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct ByteSize(pub u64);

impl ByteSize {
    pub const ZERO: ByteSize = ByteSize(0);

    pub fn kb(n: u64) -> Self {
        ByteSize(n * KB)
    }
    pub fn mb(n: u64) -> Self {
        ByteSize(n * MB)
    }
    pub fn gb(n: u64) -> Self {
        ByteSize(n * GB)
    }

    pub fn as_u64(self) -> u64 {
        self.0
    }
    pub fn as_f64(self) -> f64 {
        self.0 as f64
    }

    /// Seconds to move this many bytes at `bytes_per_sec`.
    pub fn time_at(self, bytes_per_sec: f64) -> f64 {
        if bytes_per_sec <= 0.0 {
            return f64::INFINITY;
        }
        self.0 as f64 / bytes_per_sec
    }
}

impl Add for ByteSize {
    type Output = ByteSize;
    fn add(self, rhs: ByteSize) -> ByteSize {
        ByteSize(self.0 + rhs.0)
    }
}

impl AddAssign for ByteSize {
    fn add_assign(&mut self, rhs: ByteSize) {
        self.0 += rhs.0;
    }
}

impl Sub for ByteSize {
    type Output = ByteSize;
    fn sub(self, rhs: ByteSize) -> ByteSize {
        ByteSize(self.0.saturating_sub(rhs.0))
    }
}

impl Mul<u64> for ByteSize {
    type Output = ByteSize;
    fn mul(self, rhs: u64) -> ByteSize {
        ByteSize(self.0 * rhs)
    }
}

impl fmt::Display for ByteSize {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let b = self.0;
        if b >= GB {
            write!(f, "{:.2} GiB", b as f64 / GB as f64)
        } else if b >= MB {
            write!(f, "{:.2} MiB", b as f64 / MB as f64)
        } else if b >= KB {
            write!(f, "{:.2} KiB", b as f64 / KB as f64)
        } else {
            write!(f, "{b} B")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_units() {
        assert_eq!(ByteSize(512).to_string(), "512 B");
        assert_eq!(ByteSize::kb(2).to_string(), "2.00 KiB");
        assert_eq!(ByteSize::mb(3).to_string(), "3.00 MiB");
        assert_eq!(ByteSize::gb(1).to_string(), "1.00 GiB");
    }

    #[test]
    fn arithmetic() {
        assert_eq!(ByteSize::mb(1) + ByteSize::mb(1), ByteSize::mb(2));
        assert_eq!(ByteSize::mb(3) - ByteSize::mb(1), ByteSize::mb(2));
        assert_eq!(ByteSize::mb(2) - ByteSize::mb(3), ByteSize::ZERO);
        assert_eq!(ByteSize::kb(4) * 256, ByteSize::mb(1));
    }

    #[test]
    fn transfer_time() {
        // 100 Mb/s fast Ethernet = 12.5 MB/s; 125 MB takes 10 s.
        let t = ByteSize(125_000_000).time_at(12_500_000.0);
        assert!((t - 10.0).abs() < 1e-9);
        assert!(ByteSize::mb(1).time_at(0.0).is_infinite());
    }
}
