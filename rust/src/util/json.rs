//! Minimal JSON parser/emitter (no serde): used for the AOT manifest, the
//! portal API bodies and run reports. Supports the full JSON grammar with
//! the usual numeric restriction (f64).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap so emission is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object — builder use only).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Path lookup: `j.path(&["programs", "features", "file"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("short \\u"))?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| self.err("short \\u"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2).map_err(
                                            |_| self.err("bad \\u"),
                                        )?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad \\u"))?;
                                    self.i += 6;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(
                                ch.ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(j: &Json, out: &mut String) {
    match j {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(v, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(
            r#"{"programs": {"features": {"file": "f.hlo.txt", "bytes": 123}},
                "names": ["a", "b"], "ok": true}"#,
        )
        .unwrap();
        assert_eq!(
            j.path(&["programs", "features", "file"]).unwrap().as_str(),
            Some("f.hlo.txt")
        );
        assert_eq!(
            j.path(&["programs", "features", "bytes"]).unwrap().as_u64(),
            Some(123)
        );
        assert_eq!(j.get("names").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,null,{"b":"x\"y"}],"c":false}"#;
        let j = Json::parse(src).unwrap();
        let emitted = j.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn builder() {
        let j = Json::obj()
            .set("n", 3u64)
            .set("s", "hi")
            .set("a", Json::Arr(vec![Json::Num(1.0)]));
        let text = j.to_string();
        assert_eq!(text, r#"{"a":[1],"n":3,"s":"hi"}"#);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}
