//! Small shared substrates: deterministic PRNG, hashing, JSON, byte sizes.
//!
//! Everything here is hand-rolled (no external deps) so the whole stack
//! stays auditable and deterministic across platforms.

pub mod bench;
pub mod bytes;
pub mod hash;
pub mod json;
pub mod rng;

pub use bytes::ByteSize;
pub use hash::xxhash64;
pub use rng::Rng;
