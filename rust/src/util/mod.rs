//! Small shared substrates: deterministic PRNG, hashing, JSON, byte sizes.
//!
//! Everything here is hand-rolled (no external deps) so the whole stack
//! stays auditable and deterministic across platforms.

pub mod bench;
pub mod bytes;
pub mod hash;
pub mod json;
pub mod rng;

pub use bytes::ByteSize;
pub use hash::xxhash64;
pub use rng::Rng;

/// Lock a mutex, recovering from poisoning. Coordinator threads (the
/// JSE event loop, the cluster broker) must keep serving even if some
/// other thread panicked while holding a shared lock — per-row metadata
/// stays internally consistent, so continuing with the last-written
/// state beats taking the whole coordinator down.
pub fn lock<T>(
    m: &std::sync::Mutex<T>,
) -> std::sync::MutexGuard<'_, T> {
    m.lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}
