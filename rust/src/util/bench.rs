//! Micro-benchmark harness (criterion is not available offline): warmup +
//! timed iterations, robust stats, and aligned table printing shared by
//! every `cargo bench` target and the examples.

use std::time::Instant;

/// Timing statistics over n iterations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        if self.mean_ns <= 0.0 {
            return 0.0;
        }
        items_per_iter / (self.mean_ns / 1e9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {} p50 {} p99 {} min {} (n={})",
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
            self.iters
        )
    }
}

/// Run `f` for `warmup` then `iters` timed iterations.
pub fn bench(warmup: usize, iters: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let q = |p: f64| {
        let idx = ((samples.len() as f64 - 1.0) * p).round() as usize;
        samples[idx]
    };
    Stats {
        iters,
        mean_ns: mean,
        p50_ns: q(0.5),
        p99_ns: q(0.99),
        min_ns: samples[0],
    }
}

/// Time one closure, returning (result, seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Print an aligned table: header + rows of equal arity.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> =
        header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: Vec<String>| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(header.iter().map(|s| s.to_string()).collect())
    );
    for row in rows {
        println!("{}", fmt_row(row.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let s = bench(2, 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(s.iters, 50);
        assert!(s.min_ns <= s.p50_ns);
        assert!(s.p50_ns <= s.p99_ns);
        assert!(s.mean_ns > 0.0);
    }

    #[test]
    fn throughput_math() {
        let s = Stats {
            iters: 1,
            mean_ns: 1e9,
            p50_ns: 1e9,
            p99_ns: 1e9,
            min_ns: 1e9,
        };
        assert!((s.throughput(100.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
