//! Resource timelines for the DES: book work onto CPUs/NICs and get back
//! start/end times. These are analytic FIFO timelines (no token passing),
//! which keeps the simulator fast enough to sweep thousands of scenarios
//! (hotpath bench target: >1M bookings/s).

/// A single-server FIFO resource (e.g. a NIC serializing transfers, a
/// disk serializing reads). Booking returns [start, end).
#[derive(Debug, Clone, Default)]
pub struct SerialResource {
    next_free: f64,
    busy: f64,
}

impl SerialResource {
    pub fn new() -> Self {
        Self::default()
    }

    /// Book `duration` seconds at or after `now`. Returns (start, end).
    pub fn book(&mut self, now: f64, duration: f64) -> (f64, f64) {
        debug_assert!(duration >= 0.0);
        let start = self.next_free.max(now);
        let end = start + duration;
        self.next_free = end;
        self.busy += duration;
        (start, end)
    }

    /// Earliest time a new booking could start.
    pub fn free_at(&self, now: f64) -> f64 {
        self.next_free.max(now)
    }

    /// Total busy seconds booked.
    pub fn busy_time(&self) -> f64 {
        self.busy
    }
}

/// A `k`-slot resource (CPU with k cores / GRAM job-manager slots):
/// bookings go to the earliest-free slot.
#[derive(Debug, Clone)]
pub struct MultiSlot {
    slots: Vec<f64>,
    busy: f64,
}

impl MultiSlot {
    pub fn new(k: usize) -> Self {
        MultiSlot { slots: vec![0.0; k.max(1)], busy: 0.0 }
    }

    pub fn k(&self) -> usize {
        self.slots.len()
    }

    /// Book `duration` on the earliest-available slot at/after `now`.
    pub fn book(&mut self, now: f64, duration: f64) -> (f64, f64) {
        debug_assert!(duration >= 0.0);
        let (idx, _) = self
            .slots
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let start = self.slots[idx].max(now);
        let end = start + duration;
        self.slots[idx] = end;
        self.busy += duration;
        (start, end)
    }

    /// When all current bookings finish.
    pub fn drain_time(&self) -> f64 {
        self.slots.iter().copied().fold(0.0, f64::max)
    }

    pub fn busy_time(&self) -> f64 {
        self.busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_fifo_order() {
        let mut r = SerialResource::new();
        let (s1, e1) = r.book(0.0, 2.0);
        let (s2, e2) = r.book(0.0, 3.0);
        assert_eq!((s1, e1), (0.0, 2.0));
        assert_eq!((s2, e2), (2.0, 5.0));
        // a booking after idle time starts at `now`
        let (s3, _) = r.book(10.0, 1.0);
        assert_eq!(s3, 10.0);
        assert_eq!(r.busy_time(), 6.0);
    }

    #[test]
    fn multislot_parallelism() {
        let mut cpu = MultiSlot::new(2);
        let (s1, e1) = cpu.book(0.0, 4.0);
        let (s2, e2) = cpu.book(0.0, 4.0);
        let (s3, e3) = cpu.book(0.0, 4.0);
        assert_eq!((s1, e1), (0.0, 4.0));
        assert_eq!((s2, e2), (0.0, 4.0)); // second core
        assert_eq!((s3, e3), (4.0, 8.0)); // queues behind the earliest
        assert_eq!(cpu.drain_time(), 8.0);
    }

    #[test]
    fn multislot_single_is_serial() {
        let mut cpu = MultiSlot::new(1);
        cpu.book(0.0, 1.0);
        let (s, _) = cpu.book(0.0, 1.0);
        assert_eq!(s, 1.0);
    }

    #[test]
    fn zero_slot_clamps_to_one() {
        let cpu = MultiSlot::new(0);
        assert_eq!(cpu.k(), 1);
    }
}
