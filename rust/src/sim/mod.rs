//! Discrete-event simulation substrate.
//!
//! The paper's evaluation (Fig 7) ran 130 real executions on a two-node
//! 2002 testbed. We cannot materialise 16 GB of 1 MB events in this
//! sandbox, so the sweep path runs on a deterministic virtual clock: the
//! same scheduling/placement/transfer *logic*, with compute durations
//! taken from a cost model **calibrated against the real measured PJRT
//! kernel throughput** (see EXPERIMENTS.md §Calibration). The live tokio
//! path (`cluster`) runs the identical coordination code with real
//! compute for correctness validation.
//!
//! - [`engine`]: virtual clock + event queue (closures over a world type)
//! - [`resource`]: FIFO/multi-slot resource timelines (CPU slots, NIC
//!   serialization)
//! - [`scenario`]: the GEPS run simulator used by every bench

pub mod engine;
pub mod resource;
pub mod scenario;

pub use engine::Engine;
pub use resource::{MultiSlot, SerialResource};
pub use scenario::{FailureSpec, RunReport, Scenario, ScenarioConfig};
