//! The GEPS run simulator: one complete job lifecycle on a virtual
//! cluster, driven by the *same* pull-based [`Scheduler`] policies the
//! live cluster uses.
//!
//! Lifecycle modelled (matching §4.2 + §6 of the paper):
//!
//! 1. user submits → job tuple lands in the catalogue; the JSE broker
//!    discovers it at its next poll tick (`broker_poll_s`);
//! 2. GRAM executable staging to every participating node, serialized
//!    through the leader's submission engine (`stage_overhead_s` each);
//! 3. nodes pull tasks: optional raw-data transfer (GASS; serialized on
//!    the source host's NIC, timed by `netsim`), compute (calibrated
//!    events/s × node speed), result send-back (serialized on the
//!    leader's NIC);
//! 4. node failures at configured times fail in-flight tasks, trigger the
//!    policy's recovery path, and may lose bricks (replication = 1);
//! 5. when the policy reports done, the JSE merges results
//!    (`merge_fixed_s` + bytes / `merge_bps`).
//!
//! Compute-rate calibration: `event_s` defaults come from the measured
//! PJRT kernel throughput scaled to the paper's 1 MB events — see
//! EXPERIMENTS.md §Calibration and `runtime::calibrate`.

use crate::metrics::{Registry, Snapshot};
use crate::netsim::{transfer_time, Topology, TransferSpec};
use crate::obs::health::{default_rules, evaluate};
use crate::obs::history::{sample_rows, Federation, HistoryRing};
use crate::scheduler::{NodeState, Policy, SchedCtx, Scheduler, Task};
use crate::sim::engine::Engine;
use crate::sim::resource::{MultiSlot, SerialResource};
use crate::util::ByteSize;
use crate::wire::Message;
use std::collections::BTreeMap;

/// Kill `node` at `at_s` seconds of virtual time.
#[derive(Debug, Clone)]
pub struct FailureSpec {
    pub node: String,
    pub at_s: f64,
}

/// Join `node` to the grid at `at_s` seconds of virtual time (elastic
/// membership churn — the DES counterpart of `geps add-node`).
#[derive(Debug, Clone)]
pub struct JoinSpec {
    pub node: String,
    pub speed: f64,
    pub slots: usize,
    pub at_s: f64,
}

/// Full description of one simulated run.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub topology: Topology,
    /// per-node relative CPU speed (missing = 1.0)
    pub speeds: BTreeMap<String, f64>,
    /// per-node task slots (missing = 1)
    pub slots: BTreeMap<String, usize>,
    pub policy: Policy,
    pub n_events: usize,
    /// raw bytes per event (paper: ~1 MB)
    pub event_bytes: u64,
    pub events_per_brick: usize,
    pub replication: usize,
    /// seconds of compute per event at speed 1.0 (calibrated)
    pub event_s: f64,
    /// JSE broker poll period (job discovery latency), §4.2
    pub broker_poll_s: f64,
    /// GRAM executable-staging + submission cost per node, serialized
    pub stage_overhead_s: f64,
    /// per-task dispatch overhead (RSL synthesis + GRAM submit), the
    /// "many smaller files" cost of §6
    pub task_overhead_s: f64,
    /// result bytes per processed event (selectivity × record size)
    pub result_bytes_per_event: u64,
    /// merge cost at the JSE
    pub merge_fixed_s: f64,
    pub merge_bps: f64,
    /// local disk read rate for brick-resident data
    pub disk_bps: f64,
    /// parallel TCP streams for raw/result transfers (GridFTP ext.)
    pub streams: u32,
    /// prototype mode (§6): raw data starts at the leader and must be
    /// GASS-transferred even for locality tasks. Grid-brick mode = false:
    /// bricks are pre-placed on node disks.
    pub raw_at_leader: bool,
    /// §7 extension: submit GRAM jobs to all nodes concurrently instead
    /// of through the prototype's single-threaded JSE loop. false =
    /// faithful to the 2003 prototype.
    pub stage_parallel: bool,
    pub failures: Vec<FailureSpec>,
    /// nodes that join the grid mid-run (kill+join churn scenarios)
    pub joins: Vec<JoinSpec>,
    /// telemetry history ring capacity, `[obs] history_ticks`
    pub history_ticks: usize,
    /// telemetry sampling cadence in *virtual* seconds,
    /// `[obs] history_interval` — never wall clock
    pub history_interval_s: f64,
}

impl ScenarioConfig {
    /// Baseline parameterisation shared by the paper-reproduction benches;
    /// see EXPERIMENTS.md §Calibration for where each number comes from.
    pub fn paper_defaults(topology: Topology, policy: Policy, n_events: usize) -> Self {
        ScenarioConfig {
            topology,
            speeds: BTreeMap::new(),
            slots: BTreeMap::new(),
            policy,
            n_events,
            event_bytes: 1 << 20, // 1 MB/event (§1.1)
            events_per_brick: 250,
            replication: 1,
            event_s: 0.045, // calibrated: see runtime::calibrate + EXPERIMENTS.md
            broker_poll_s: 10.0,
            stage_overhead_s: 70.0,
            task_overhead_s: 1.0,
            result_bytes_per_event: 100 << 10, // ~10% selectivity
            merge_fixed_s: 5.0,
            merge_bps: 100_000_000.0,
            disk_bps: 80_000_000.0, // node-local sequential read (RAID-ish)
            streams: 1,
            raw_at_leader: true, // the prototype §6 behaviour
            stage_parallel: false,
            failures: Vec::new(),
            joins: Vec::new(),
            history_ticks: 64,
            history_interval_s: 30.0,
        }
    }

    /// Fig 7 "GEPS" configuration: gandalf + hobbit, heterogeneous
    /// speeds, **grid-brick mode** — the event data was distributed to
    /// the nodes' disks before the timed window (§6: raw data is
    /// transferred "before a job can be submitted"; §4: "data should not
    /// be moved when applying for a job submission"). The crossover then
    /// comes from the serialized per-node GRAM/JSE overhead (paid twice)
    /// against the parallel compute gain — which is exactly the
    /// granularity tradeoff Fig 7 plots.
    pub fn fig7_geps(n_events: usize) -> Self {
        let mut cfg = Self::paper_defaults(
            Topology::paper_testbed(),
            Policy::Locality,
            n_events,
        );
        cfg.speeds.insert("gandalf".into(), 0.8);
        cfg.speeds.insert("hobbit".into(), 1.0);
        cfg.raw_at_leader = false;
        cfg
    }

    /// Fig 7 "hobbit only": the same job restricted to the single
    /// tightly-coupled node (one staging, data already local).
    pub fn fig7_hobbit_only(n_events: usize) -> Self {
        let mut t = Topology::new("jse", crate::netsim::Link::lan_fast_ethernet());
        t.add_host("hobbit");
        let mut cfg = Self::paper_defaults(t, Policy::Locality, n_events);
        cfg.speeds.insert("hobbit".into(), 1.0);
        cfg.raw_at_leader = false;
        cfg
    }

    /// The §6 prototype variant that *does* GASS-stage raw data from the
    /// JSE inside the timed window (used by the granularity ablation).
    pub fn fig7_geps_staged(n_events: usize) -> Self {
        let mut cfg = Self::fig7_geps(n_events);
        cfg.raw_at_leader = true;
        cfg
    }

    fn speed(&self, node: &str) -> f64 {
        self.speeds.get(node).copied().unwrap_or(1.0)
    }

    fn node_slots(&self, node: &str) -> usize {
        self.slots.get(node).copied().unwrap_or(1)
    }

    /// Build the scheduler context: nodes + brick placement.
    pub fn build_ctx(&self) -> SchedCtx {
        let workers = self.topology.workers();
        let nodes = workers
            .iter()
            .map(|w| crate::scheduler::NodeState {
                name: w.clone(),
                speed: self.speed(w),
                slots: self.node_slots(w),
                up: true,
            })
            .collect();
        let placements = crate::brick::split_events(
            &crate::brick::SplitConfig {
                dataset: 1,
                events_per_brick: self.events_per_brick,
                replication: self.replication,
            },
            self.n_events,
            &workers,
        );
        let bricks = placements
            .iter()
            .map(|p| crate::scheduler::BrickState {
                id: p.id,
                n_events: p.range.1 - p.range.0,
                bytes: (p.range.1 - p.range.0) as u64 * self.event_bytes,
                holders: p.holders.clone(),
            })
            .collect();
        SchedCtx {
            nodes,
            bricks,
            leader: self.topology.leader().to_string(),
        }
    }
}

/// Result of one simulated run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub policy: &'static str,
    pub n_events: usize,
    /// submission → merged result, virtual seconds (Fig 7's y-axis)
    pub makespan_s: f64,
    pub events_processed: usize,
    pub tasks_completed: usize,
    pub tasks_failed: usize,
    /// raw event bytes moved over the network (staging + steals)
    pub raw_bytes_moved: u64,
    pub result_bytes: u64,
    /// per-node CPU busy seconds
    pub node_busy_s: BTreeMap<String, f64>,
    /// bricks that lost all replicas (data unavailable)
    pub lost_bricks: usize,
    /// job finished cleanly (all non-lost work processed)
    pub completed: bool,
    /// canonical `GET /metrics/history` body sampled on virtual-time
    /// ticks — byte-identical across same-config runs
    pub history_body: String,
    /// canonical `GET /health` body evaluated over the final window
    pub health_body: String,
}

impl RunReport {
    /// Mean worker utilisation over the makespan.
    pub fn utilization(&self) -> f64 {
        if self.makespan_s <= 0.0 || self.node_busy_s.is_empty() {
            return 0.0;
        }
        let busy: f64 = self.node_busy_s.values().sum();
        busy / (self.makespan_s * self.node_busy_s.len() as f64)
    }
}

struct World {
    cfg: ScenarioConfig,
    ctx: SchedCtx,
    sched: Box<dyn Scheduler>,
    nics: BTreeMap<String, SerialResource>,
    cpus: BTreeMap<String, MultiSlot>,
    running: BTreeMap<String, usize>,
    eligible_at: BTreeMap<String, f64>,
    down_at: BTreeMap<String, f64>,
    /// prototype mode: node that raw data was pre-staged to, per brick
    staged_to: BTreeMap<crate::brick::BrickId, String>,
    raw_bytes_moved: u64,
    result_bytes: u64,
    events_processed: usize,
    tasks_completed: usize,
    tasks_failed: usize,
    last_result_arrival: f64,
    finish_time: Option<f64>,
    /// per-node private metric registries — federated to the leader
    /// through real `MetricsReport` wire frames on the telemetry tick
    node_regs: BTreeMap<String, Registry>,
    federation: Federation,
    ring: HistoryRing,
    /// next report sequence number per node
    obs_seq: BTreeMap<String, u64>,
    /// consecutive ticks where the engine processed nothing but the
    /// tick itself (the never-finishing-run ticker brake)
    obs_idle: u32,
    obs_last_processed: u64,
    /// ticker paused (idle brake fired); completions restart it
    obs_stopped: bool,
    /// the finish-time tick was recorded — no further samples
    obs_done: bool,
}

impl World {
    fn is_down(&self, node: &str, at: f64) -> bool {
        self.down_at.get(node).map(|t| *t <= at).unwrap_or(false)
    }

    /// Where this task's raw bytes actually come from at dispatch time.
    /// Pre-staged bricks (prototype mode) are already local; a brick that
    /// failed over to a different node than it was staged to must be
    /// re-pulled from the leader.
    fn effective_source(&self, node: &str, task: &Task) -> Option<String> {
        if let Some(s) = &task.source {
            return Some(s.clone());
        }
        if self.cfg.raw_at_leader {
            match self.staged_to.get(&task.brick) {
                Some(staged) if staged == node => None, // arrived pre-staged
                _ => Some(self.ctx.leader.clone()),
            }
        } else {
            None
        }
    }
}

/// A runnable scenario.
pub struct Scenario;

impl Scenario {
    /// Simulate one job run; deterministic for a given config.
    pub fn run(cfg: ScenarioConfig) -> RunReport {
        let ctx = cfg.build_ctx();
        let sched = cfg.policy.build(&ctx);
        let mut nics = BTreeMap::new();
        let mut cpus = BTreeMap::new();
        let mut running = BTreeMap::new();
        let mut node_regs = BTreeMap::new();
        for h in cfg.topology.hosts() {
            nics.insert(h.clone(), SerialResource::new());
        }
        for w in cfg.topology.workers() {
            cpus.insert(w.clone(), MultiSlot::new(cfg.node_slots(&w)));
            running.insert(w.clone(), 0);
            node_regs.insert(w.clone(), Registry::new());
        }
        let ring = HistoryRing::new(
            cfg.history_ticks,
            (cfg.history_interval_s * 1e9) as u64,
        );

        let mut world = World {
            ctx,
            sched,
            nics,
            cpus,
            running,
            eligible_at: BTreeMap::new(),
            down_at: BTreeMap::new(),
            staged_to: BTreeMap::new(),
            raw_bytes_moved: 0,
            result_bytes: 0,
            events_processed: 0,
            tasks_completed: 0,
            tasks_failed: 0,
            last_result_arrival: 0.0,
            finish_time: None,
            node_regs,
            federation: Federation::new(),
            ring,
            obs_seq: BTreeMap::new(),
            obs_idle: 0,
            obs_last_processed: 0,
            obs_stopped: false,
            obs_done: false,
            cfg,
        };

        let mut eng: Engine<World> = Engine::new();

        // failures
        for f in world.cfg.failures.clone() {
            let node = f.node.clone();
            eng.schedule(f.at_s, move |e, w| fail_node(e, w, &node));
        }

        // elastic-membership joins
        for j in world.cfg.joins.clone() {
            eng.schedule(j.at_s, move |e, w| join_node(e, w, &j));
        }

        // telemetry: federate + sample on the virtual-time cadence
        eng.schedule(world.cfg.history_interval_s, obs_tick);

        // 1. broker discovers the job at the next poll tick
        let poll = world.cfg.broker_poll_s;
        eng.schedule(poll, |e, w| {
            // 2. per node, serialized through the single-threaded JSE (as
            //    the 2003 prototype was): GRAM executable staging, then —
            //    in prototype mode (§6: "raw event data will firstly be
            //    transferred to grid nodes in accordance with the ...
            //    distribution specification") — the node's ENTIRE raw
            //    allotment is GASS-transferred before its job may start.
            let workers = w.cfg.topology.workers();
            let prestage = w.cfg.raw_at_leader
                && w.cfg.policy != Policy::Central;
            let mut submit = SerialResource::new();
            let leader = w.ctx.leader.clone();
            for node in workers {
                let stage_end = if w.cfg.stage_parallel {
                    // §7 extension: concurrent submission
                    e.now() + w.cfg.stage_overhead_s
                } else {
                    submit.book(e.now(), w.cfg.stage_overhead_s).1
                };
                let mut ready = stage_end;
                if prestage {
                    let bricks: Vec<(crate::brick::BrickId, u64)> = w
                        .ctx
                        .bricks
                        .iter()
                        .filter(|b| b.holders.first() == Some(&node))
                        .map(|b| (b.id, b.bytes))
                        .collect();
                    let bytes: u64 = bricks.iter().map(|(_, b)| *b).sum();
                    if bytes > 0 {
                        let link = w.cfg.topology.link(&leader, &node);
                        let dur = transfer_time(
                            &link,
                            &TransferSpec {
                                bytes: ByteSize(bytes),
                                streams: w.cfg.streams,
                            },
                        );
                        // the transfer is part of job setup: it can only
                        // start after this node's GRAM staging completes
                        let (_, xfer_end) = w
                            .nics
                            .get_mut(&leader)
                            .unwrap()
                            .book(stage_end, dur);
                        w.raw_bytes_moved += bytes;
                        ready = ready.max(xfer_end);
                    }
                    for (id, _) in bricks {
                        w.staged_to.insert(id, node.clone());
                    }
                }
                w.eligible_at.insert(node.clone(), ready);
                let n = node.clone();
                e.schedule_at(ready, move |e2, w2| kick(e2, w2, &n));
            }
        });

        eng.run(&mut world);

        let makespan = world.finish_time.unwrap_or_else(|| {
            // job never completed (e.g. all nodes dead): report the time
            // the system went quiescent
            world.last_result_arrival.max(eng.now())
        });

        let lost = lost_bricks(&world);
        let node_busy_s = world
            .cpus
            .iter()
            .map(|(n, c)| (n.clone(), c.busy_time()))
            .collect();

        RunReport {
            policy: world.sched.name(),
            n_events: world.cfg.n_events,
            makespan_s: makespan,
            events_processed: world.events_processed,
            tasks_completed: world.tasks_completed,
            tasks_failed: world.tasks_failed,
            raw_bytes_moved: world.raw_bytes_moved,
            result_bytes: world.result_bytes,
            node_busy_s,
            lost_bricks: lost,
            completed: world.finish_time.is_some(),
            history_body: world.ring.render(None, None),
            health_body: evaluate(&world.ring, &default_rules()).render(),
        }
    }
}

/// One telemetry tick: every live node ships its cumulative snapshot
/// to the leader **through the real wire codec** (encode → frame →
/// decode → seq-guarded fold — the exact `MetricsReport` path the live
/// heartbeat channel uses), then the federated view is sampled into the
/// history ring. Entirely virtual-time driven, so two runs of the same
/// config record byte-identical windows.
fn federate_and_record(eng: &mut Engine<World>, w: &mut World) {
    if w.obs_done {
        return;
    }
    let now = eng.now();
    for node in w.node_regs.keys().cloned().collect::<Vec<_>>() {
        if w.is_down(&node, now) {
            continue; // dead: its last accepted report is retained
        }
        let seq = w.obs_seq.entry(node.clone()).or_insert(0);
        *seq += 1;
        let frame = Message::MetricsReport {
            node: node.clone(),
            seq: *seq,
            payload: Snapshot::from_registry(&w.node_regs[&node]).encode(),
        }
        .encode();
        if let Ok((Message::MetricsReport { node, seq, payload }, _)) =
            Message::decode(&frame)
        {
            if let Some(snap) = Snapshot::decode(&payload) {
                w.federation.report(&node, seq, snap);
            }
        }
    }
    // the DES has no shared leader registry: cluster-row series come
    // from an empty one; killed nodes are marked heartbeat-stale the
    // way the live monitor would see them
    let shared = Registry::new();
    let mut rows = sample_rows(&shared, &w.federation.snapshots());
    for node in w.node_regs.keys() {
        rows.insert(
            (node.clone(), "node.hb_stale".into()),
            u64::from(w.is_down(node, now)),
        );
    }
    w.ring.record_tick(rows);
    if w.finish_time.is_some() {
        w.obs_done = true;
    }
}

fn obs_tick(eng: &mut Engine<World>, w: &mut World) {
    federate_and_record(eng, w);
    if w.obs_done {
        return;
    }
    // idle brake: a run that can never finish (all nodes dead) must not
    // tick forever — pause after 2 ticks where the engine processed
    // nothing but the ticks themselves; progress restarts the ticker
    let processed = eng.processed();
    if processed.saturating_sub(w.obs_last_processed) <= 1 {
        w.obs_idle += 1;
    } else {
        w.obs_idle = 0;
    }
    w.obs_last_processed = processed;
    if w.obs_idle >= 2 {
        w.obs_stopped = true;
        return;
    }
    eng.schedule(w.cfg.history_interval_s, obs_tick);
}

/// Restart a paused ticker (called from the progress paths).
fn obs_resume(eng: &mut Engine<World>, w: &mut World) {
    if w.obs_stopped && !w.obs_done {
        w.obs_stopped = false;
        w.obs_idle = 0;
        w.obs_last_processed = eng.processed();
        eng.schedule(w.cfg.history_interval_s, obs_tick);
    }
}

/// Elastic membership: fold a newcomer into the running world — fresh
/// NIC/CPU resources, a private metrics registry, a context entry and
/// an `on_node_up` to the policy — then stage and kick it.
fn join_node(eng: &mut Engine<World>, w: &mut World, j: &JoinSpec) {
    if w.ctx.node(&j.node).is_some() {
        return; // names are never recycled within a job
    }
    w.cfg.topology.add_host(&j.node);
    w.cfg.speeds.insert(j.node.clone(), j.speed);
    w.cfg.slots.insert(j.node.clone(), j.slots);
    w.nics.insert(j.node.clone(), SerialResource::new());
    w.cpus.insert(j.node.clone(), MultiSlot::new(j.slots.max(1)));
    w.running.insert(j.node.clone(), 0);
    w.node_regs.insert(j.node.clone(), Registry::new());
    w.ctx.add_node(NodeState {
        name: j.node.clone(),
        speed: j.speed,
        slots: j.slots.max(1),
        up: true,
    });
    let ctx = w.ctx.clone();
    w.sched.on_node_up(&j.node, &ctx);
    // the newcomer pays GRAM staging before its first pull
    let ready = eng.now() + w.cfg.stage_overhead_s;
    w.eligible_at.insert(j.node.clone(), ready);
    let n = j.node.clone();
    eng.schedule_at(ready, move |e, w2| kick(e, w2, &n));
    obs_resume(eng, w);
}

fn lost_bricks(w: &World) -> usize {
    // bricks whose every holder is down and that were never completed:
    // approximate via scheduler doneness: tasks_failed counted separately;
    // here we count bricks with zero live holders.
    w.ctx
        .bricks
        .iter()
        .filter(|b| {
            b.holders.iter().all(|h| {
                w.down_at.contains_key(h)
            })
        })
        .count()
}

fn fail_node(eng: &mut Engine<World>, w: &mut World, node: &str) {
    if w.down_at.contains_key(node) {
        return;
    }
    w.down_at.insert(node.to_string(), eng.now());
    if let Some(n) = w.ctx.nodes.iter_mut().find(|n| n.name == node) {
        n.up = false;
    }
    let ctx = w.ctx.clone();
    w.sched.on_node_down(node, &ctx);
    obs_resume(eng, w);
    kick_all(eng, w);
}

fn kick_all(eng: &mut Engine<World>, w: &mut World) {
    for node in w.cfg.topology.workers() {
        kick(eng, w, &node);
    }
}

/// Try to dispatch work to `node` until its slots are full or the policy
/// has nothing for it.
fn kick(eng: &mut Engine<World>, w: &mut World, node: &str) {
    let now = eng.now();
    if w.is_down(node, now) || w.finish_time.is_some() {
        return;
    }
    let eligible = w.eligible_at.get(node).copied().unwrap_or(f64::MAX);
    if now < eligible {
        return; // staging not finished; a kick is scheduled for then
    }
    loop {
        let slots = w.cfg.node_slots(node);
        if w.running[node] >= slots {
            return;
        }
        let ctx = w.ctx.clone();
        let task = match w.sched.next_task(node, &ctx) {
            Some(t) => t,
            None => return,
        };
        dispatch(eng, w, node, task);
    }
}

fn dispatch(eng: &mut Engine<World>, w: &mut World, node: &str, task: Task) {
    let now = eng.now();
    *w.running.get_mut(node).unwrap() += 1;
    if let Some(reg) = w.node_regs.get(node) {
        reg.gauge("node.tasks_in_flight").add(1);
    }

    let n_events = task.n_events();
    let bytes = n_events as u64 * w.cfg.event_bytes;

    // per-task dispatch overhead (RSL synth + GRAM submit)
    let t0 = now + w.cfg.task_overhead_s;

    // raw data movement
    let data_ready = match w.effective_source(node, &task) {
        Some(src) if src != node => {
            let link = w.cfg.topology.link(&src, node);
            let dur = transfer_time(
                &link,
                &TransferSpec { bytes: ByteSize(bytes), streams: w.cfg.streams },
            );
            w.raw_bytes_moved += bytes;
            let (_, end) = w.nics.get_mut(&src).unwrap().book(t0, dur);
            end
        }
        _ => {
            // local disk read
            t0 + bytes as f64 / w.cfg.disk_bps
        }
    };

    // compute
    let speed = w.cfg.speed(node).max(0.01);
    let compute_s = n_events as f64 * w.cfg.event_s / speed;
    let (_, compute_end) =
        w.cpus.get_mut(node).unwrap().book(data_ready, compute_s);

    // result send-back, serialized on the leader NIC
    let res_bytes = n_events as u64 * w.cfg.result_bytes_per_event;
    let leader = w.ctx.leader.clone();
    let link = w.cfg.topology.link(node, &leader);
    let res_dur = transfer_time(
        &link,
        &TransferSpec { bytes: ByteSize(res_bytes), streams: w.cfg.streams },
    );
    let (_, result_arrival) =
        w.nics.get_mut(&leader).unwrap().book(compute_end, res_dur);

    let node_owned = node.to_string();
    eng.schedule_at(result_arrival, move |e, w| {
        complete(e, w, &node_owned, task, compute_end, result_arrival, res_bytes);
    });
}

#[allow(clippy::too_many_arguments)]
fn complete(
    eng: &mut Engine<World>,
    w: &mut World,
    node: &str,
    task: Task,
    compute_end: f64,
    result_arrival: f64,
    res_bytes: u64,
) {
    *w.running.get_mut(node).unwrap() -= 1;
    if let Some(reg) = w.node_regs.get(node) {
        reg.gauge("node.tasks_in_flight").sub(1);
    }
    obs_resume(eng, w);

    // if the node died before the result fully arrived at the leader,
    // the work is void; the failure path (on_node_down) already requeued
    // it — counting it here too would double-process those events.
    if w.down_at.get(node).map(|t| *t <= result_arrival).unwrap_or(false) {
        w.tasks_failed += 1;
        if let Some(reg) = w.node_regs.get(node) {
            reg.counter("node.tasks_failed").inc();
        }
        kick_all(eng, w);
        return;
    }

    let elapsed = (compute_end - eng.now()).abs().max(1e-9);
    // report the compute-only elapsed for rate feedback
    let _ = elapsed;
    let compute_elapsed = task.n_events() as f64 * w.cfg.event_s
        / w.cfg.speed(node).max(0.01);
    w.sched.on_complete(node, &task, compute_elapsed);

    w.events_processed += task.n_events();
    w.tasks_completed += 1;
    w.result_bytes += res_bytes;
    w.last_result_arrival = result_arrival;
    if let Some(reg) = w.node_regs.get(node) {
        reg.counter("node.tasks_done").inc();
    }

    if w.sched.is_done() {
        // merge at the JSE
        let merge =
            w.cfg.merge_fixed_s + w.result_bytes as f64 / w.cfg.merge_bps;
        let finish = eng.now() + merge;
        w.finish_time = Some(finish);
        // final telemetry sample at the makespan, then the ring seals
        eng.schedule_at(finish, federate_and_record);
        return;
    }

    kick(eng, w, node);
    // completion may unblock steal/balance decisions on other nodes
    kick_all(eng, w);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_configs_run_to_completion() {
        for n in [250usize, 1000, 4000] {
            let geps = Scenario::run(ScenarioConfig::fig7_geps(n));
            assert!(geps.completed, "geps n={n}");
            assert_eq!(geps.events_processed, n);
            let single = Scenario::run(ScenarioConfig::fig7_hobbit_only(n));
            assert!(single.completed, "single n={n}");
            assert_eq!(single.events_processed, n);
        }
    }

    #[test]
    fn fig7_crossover_shape() {
        // Fig 7: single node wins on small files, GEPS wins on large.
        let small_geps = Scenario::run(ScenarioConfig::fig7_geps(250));
        let small_one = Scenario::run(ScenarioConfig::fig7_hobbit_only(250));
        assert!(
            small_one.makespan_s < small_geps.makespan_s,
            "single {:.1}s vs geps {:.1}s at 250 events",
            small_one.makespan_s,
            small_geps.makespan_s
        );
        let big_geps = Scenario::run(ScenarioConfig::fig7_geps(8000));
        let big_one = Scenario::run(ScenarioConfig::fig7_hobbit_only(8000));
        assert!(
            big_geps.makespan_s < big_one.makespan_s,
            "geps {:.1}s vs single {:.1}s at 8000 events",
            big_geps.makespan_s,
            big_one.makespan_s
        );
    }

    #[test]
    fn deterministic() {
        let a = Scenario::run(ScenarioConfig::fig7_geps(2000));
        let b = Scenario::run(ScenarioConfig::fig7_geps(2000));
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.raw_bytes_moved, b.raw_bytes_moved);
    }

    #[test]
    fn grid_brick_mode_moves_no_raw_bytes() {
        let r = Scenario::run(ScenarioConfig::fig7_geps(2000));
        assert!(r.completed);
        assert_eq!(r.raw_bytes_moved, 0);
        // and it beats the §6 prototype variant that stages raw data
        let proto = Scenario::run(ScenarioConfig::fig7_geps_staged(2000));
        assert!(proto.raw_bytes_moved > 0);
        assert!(r.makespan_s < proto.makespan_s);
    }

    #[test]
    fn central_policy_moves_all_raw_bytes() {
        let mut cfg = ScenarioConfig::paper_defaults(
            Topology::lan_cluster(4, crate::netsim::Link::lan_fast_ethernet()),
            Policy::Central,
            1000,
        );
        cfg.raw_at_leader = false; // central ignores this; staging is explicit
        let r = Scenario::run(cfg);
        assert!(r.completed);
        assert_eq!(r.raw_bytes_moved, 1000 * (1 << 20));
    }

    #[test]
    fn failure_with_replication_still_completes() {
        let mut cfg = ScenarioConfig::paper_defaults(
            Topology::lan_cluster(4, crate::netsim::Link::lan_fast_ethernet()),
            Policy::Locality,
            2000,
        );
        cfg.replication = 2;
        cfg.raw_at_leader = false;
        cfg.failures = vec![FailureSpec { node: "node1".into(), at_s: 60.0 }];
        let r = Scenario::run(cfg);
        assert!(r.completed, "report: {r:?}");
        assert_eq!(r.events_processed, 2000);
        assert_eq!(r.lost_bricks, 0);
    }

    #[test]
    fn failure_without_replication_loses_bricks() {
        let mut cfg = ScenarioConfig::paper_defaults(
            Topology::lan_cluster(4, crate::netsim::Link::lan_fast_ethernet()),
            Policy::Locality,
            2000,
        );
        cfg.replication = 1;
        cfg.raw_at_leader = false;
        cfg.failures = vec![FailureSpec { node: "node1".into(), at_s: 30.0 }];
        let r = Scenario::run(cfg);
        // the job still terminates, but with data loss reported
        assert!(r.lost_bricks > 0 || r.events_processed == 2000);
    }

    #[test]
    fn more_nodes_scale_locality_but_saturate_central() {
        // large workload so the (faithfully serialized, §4.2) per-node
        // GRAM staging amortizes
        let run = |policy: Policy, n_nodes: usize| {
            let mut cfg = ScenarioConfig::paper_defaults(
                Topology::lan_cluster(
                    n_nodes,
                    crate::netsim::Link::lan_fast_ethernet(),
                ),
                policy,
                32_000,
            );
            cfg.events_per_brick = 500;
            cfg.raw_at_leader = false;
            Scenario::run(cfg).makespan_s
        };
        // locality improves substantially 2 -> 8 nodes on big jobs. It
        // is NOT linear: the serialized per-node GRAM staging (faithful
        // to the 2003 single-threaded JSE) caps it — exactly the kind of
        // inefficiency the paper's §7 future work targets.
        let loc2 = run(Policy::Locality, 2);
        let loc8 = run(Policy::Locality, 8);
        assert!(loc8 < 0.75 * loc2, "loc2 {loc2:.0} loc8 {loc8:.0}");
        // central is bottlenecked by the leader NIC: far from linear
        let cen2 = run(Policy::Central, 2);
        let cen8 = run(Policy::Central, 8);
        assert!(cen8 > cen2 / 3.0, "cen2 {cen2:.0} cen8 {cen8:.0}");
        // and locality beats central at scale
        assert!(loc8 < cen8);
    }

    #[test]
    fn telemetry_bodies_are_byte_identical_across_runs() {
        // kill+join churn: node1 dies mid-run, fresh1 joins — the
        // federated history and health bodies must still be exactly
        // reproducible (the tentpole's determinism contract)
        let mk = || {
            let mut cfg = ScenarioConfig::paper_defaults(
                Topology::lan_cluster(4, crate::netsim::Link::lan_fast_ethernet()),
                Policy::Locality,
                4000,
            );
            cfg.raw_at_leader = false;
            cfg.replication = 2;
            cfg.history_interval_s = 20.0;
            cfg.failures =
                vec![FailureSpec { node: "node1".into(), at_s: 120.0 }];
            cfg.joins = vec![JoinSpec {
                node: "fresh1".into(),
                speed: 1.0,
                slots: 1,
                at_s: 150.0,
            }];
            cfg
        };
        let a = Scenario::run(mk());
        let b = Scenario::run(mk());
        assert!(a.completed, "churn run must still finish");
        assert_eq!(
            a.history_body, b.history_body,
            "/metrics/history must be byte-identical across same-seed runs"
        );
        assert_eq!(
            a.health_body, b.health_body,
            "/health must be byte-identical across same-seed runs"
        );
        assert!(
            a.history_body.contains("\"node\":\"fresh1\""),
            "joined node must federate: {}",
            a.history_body
        );
        // the killed node goes heartbeat-stale → judged unhealthy
        assert!(
            a.health_body
                .contains("\"node\":\"node1\",\"verdict\":\"unhealthy\""),
            "{}",
            a.health_body
        );
    }

    #[test]
    fn joined_node_steals_work_and_reports_metrics() {
        let mut cfg = ScenarioConfig::paper_defaults(
            Topology::lan_cluster(2, crate::netsim::Link::lan_fast_ethernet()),
            Policy::Gfarm,
            4000,
        );
        cfg.raw_at_leader = false;
        cfg.history_interval_s = 20.0;
        cfg.joins = vec![JoinSpec {
            node: "fresh1".into(),
            speed: 1.0,
            slots: 1,
            at_s: 100.0,
        }];
        let r = Scenario::run(cfg);
        assert!(r.completed);
        assert_eq!(r.events_processed, 4000);
        assert!(
            r.node_busy_s.get("fresh1").copied().unwrap_or(0.0) > 0.0,
            "newcomer must end up computing (work-stealing policy)"
        );
        assert!(
            r.history_body
                .contains("\"node\":\"fresh1\",\"name\":\"node.tasks_done\""),
            "newcomer's federated counters must reach the ring: {}",
            r.history_body
        );
    }

    #[test]
    fn utilization_bounded() {
        let r = Scenario::run(ScenarioConfig::fig7_geps(4000));
        let u = r.utilization();
        assert!(u > 0.0 && u <= 1.0, "utilization {u}");
    }
}
