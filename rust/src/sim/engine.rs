//! Minimal deterministic discrete-event engine.
//!
//! Events are boxed closures over a user "world" type `W`; ties in time
//! break by insertion sequence so runs are exactly reproducible.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

type EventFn<W> = Box<dyn FnOnce(&mut Engine<W>, &mut W)>;

struct Scheduled<W> {
    time: f64,
    seq: u64,
    f: EventFn<W>,
}

impl<W> PartialEq for Scheduled<W> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<W> Eq for Scheduled<W> {}
impl<W> PartialOrd for Scheduled<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<W> Ord for Scheduled<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap via reversed compare; NaN-free by construction
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The simulation engine: a virtual clock and a pending-event queue.
pub struct Engine<W> {
    now: f64,
    seq: u64,
    queue: BinaryHeap<Scheduled<W>>,
    processed: u64,
}

impl<W> Default for Engine<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W> Engine<W> {
    pub fn new() -> Self {
        Engine { now: 0.0, seq: 0, queue: BinaryHeap::new(), processed: 0 }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `f` to run `delay` seconds from now.
    pub fn schedule(
        &mut self,
        delay: f64,
        f: impl FnOnce(&mut Engine<W>, &mut W) + 'static,
    ) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        let time = self.now + delay.max(0.0);
        self.seq += 1;
        self.queue.push(Scheduled { time, seq: self.seq, f: Box::new(f) });
    }

    /// Schedule at an absolute virtual time (>= now).
    pub fn schedule_at(
        &mut self,
        time: f64,
        f: impl FnOnce(&mut Engine<W>, &mut W) + 'static,
    ) {
        self.schedule((time - self.now).max(0.0), f);
    }

    /// Run until the queue drains (or `max_events` as a runaway guard).
    /// Returns the final virtual time.
    pub fn run(&mut self, world: &mut W) -> f64 {
        self.run_limited(world, u64::MAX)
    }

    pub fn run_limited(&mut self, world: &mut W, max_events: u64) -> f64 {
        let mut n = 0u64;
        while let Some(ev) = self.queue.pop() {
            debug_assert!(ev.time >= self.now);
            self.now = ev.time;
            (ev.f)(self, world);
            self.processed += 1;
            n += 1;
            if n >= max_events {
                break;
            }
        }
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut eng: Engine<Vec<u32>> = Engine::new();
        let mut world = Vec::new();
        eng.schedule(3.0, |_, w: &mut Vec<u32>| w.push(3));
        eng.schedule(1.0, |_, w| w.push(1));
        eng.schedule(2.0, |_, w| w.push(2));
        let end = eng.run(&mut world);
        assert_eq!(world, vec![1, 2, 3]);
        assert_eq!(end, 3.0);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut eng: Engine<Vec<u32>> = Engine::new();
        let mut world = Vec::new();
        for i in 0..10 {
            eng.schedule(1.0, move |_, w: &mut Vec<u32>| w.push(i));
        }
        eng.run(&mut world);
        assert_eq!(world, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn events_can_schedule_events() {
        let mut eng: Engine<Vec<f64>> = Engine::new();
        let mut world = Vec::new();
        eng.schedule(1.0, |e, w: &mut Vec<f64>| {
            w.push(e.now());
            e.schedule(2.0, |e2, w2: &mut Vec<f64>| w2.push(e2.now()));
        });
        let end = eng.run(&mut world);
        assert_eq!(world, vec![1.0, 3.0]);
        assert_eq!(end, 3.0);
    }

    #[test]
    fn chain_recursion() {
        // a self-rescheduling ticker
        struct W {
            ticks: u32,
        }
        fn tick(e: &mut Engine<W>, w: &mut W) {
            w.ticks += 1;
            if w.ticks < 100 {
                e.schedule(0.5, tick);
            }
        }
        let mut eng = Engine::new();
        let mut w = W { ticks: 0 };
        eng.schedule(0.5, tick);
        let end = eng.run(&mut w);
        assert_eq!(w.ticks, 100);
        assert!((end - 50.0).abs() < 1e-9);
    }

    #[test]
    fn run_limited_guards() {
        struct W;
        fn forever(e: &mut Engine<W>, _w: &mut W) {
            e.schedule(1.0, forever);
        }
        let mut eng = Engine::new();
        eng.schedule(1.0, forever);
        eng.run_limited(&mut W, 1000);
        assert_eq!(eng.processed(), 1000);
    }

    #[test]
    fn schedule_at_absolute() {
        let mut eng: Engine<Vec<f64>> = Engine::new();
        let mut w = Vec::new();
        eng.schedule(5.0, |e, w: &mut Vec<f64>| {
            // past-time schedules clamp to now
            e.schedule_at(1.0, |e2, w2: &mut Vec<f64>| w2.push(e2.now()));
            w.push(e.now());
        });
        eng.run(&mut w);
        assert_eq!(w, vec![5.0, 5.0]);
    }
}
