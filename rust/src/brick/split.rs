//! Brick splitting and placement: turn an event stream into bricks and
//! decide which node's disk each brick (and its replicas) lives on.
//!
//! Placement uses rendezvous (highest-random-weight) hashing so that
//! adding/removing a node only moves the bricks that must move — the
//! paper's scalability claim ("just a matter of adding more Grid nodes",
//! §4) depends on placement not reshuffling the world.

use crate::brick::BrickId;
use crate::events::model::Event;
use crate::util::hash::hash_str;

/// How a dataset is split into bricks.
#[derive(Debug, Clone)]
pub struct SplitConfig {
    pub dataset: u32,
    /// target events per brick (the paper's "granularity", Fig 7 x-axis
    /// divided by brick count)
    pub events_per_brick: usize,
    /// replication factor (1 = no replicas; §7 future work)
    pub replication: usize,
}

impl Default for SplitConfig {
    fn default() -> Self {
        SplitConfig { dataset: 1, events_per_brick: 512, replication: 1 }
    }
}

/// A brick's contents plus where its replicas live.
#[derive(Debug, Clone)]
pub struct BrickPlacement {
    pub id: BrickId,
    /// indices into the event slice: [start, end)
    pub range: (usize, usize),
    /// node names holding a replica, primary first
    pub holders: Vec<String>,
}

/// Split `n_events` into brick ranges.
pub fn split_ranges(n_events: usize, events_per_brick: usize) -> Vec<(usize, usize)> {
    let epb = events_per_brick.max(1);
    let mut out = Vec::new();
    let mut start = 0;
    while start < n_events {
        let end = (start + epb).min(n_events);
        out.push((start, end));
        start = end;
    }
    out
}

/// Rendezvous hashing: pick the top-`k` nodes for a brick.
pub fn placement_nodes(id: BrickId, nodes: &[String], k: usize) -> Vec<String> {
    let mut scored: Vec<(u64, &String)> = nodes
        .iter()
        .map(|n| {
            let key = format!("{id}@{n}");
            (hash_str(&key, 0xB81C), n)
        })
        .collect();
    scored.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(b.1)));
    scored.into_iter().take(k.min(nodes.len())).map(|(_, n)| n.clone()).collect()
}

/// Split events into bricks and place them on nodes.
pub fn split_events(
    cfg: &SplitConfig,
    n_events: usize,
    nodes: &[String],
) -> Vec<BrickPlacement> {
    assert!(!nodes.is_empty(), "cannot place bricks on zero nodes");
    split_ranges(n_events, cfg.events_per_brick)
        .into_iter()
        .enumerate()
        .map(|(seq, range)| {
            let id = BrickId::new(cfg.dataset, seq as u32);
            BrickPlacement {
                id,
                range,
                holders: placement_nodes(id, nodes, cfg.replication.max(1)),
            }
        })
        .collect()
}

/// Slice helper: the events belonging to a placement.
pub fn brick_events<'a>(events: &'a [Event], p: &BrickPlacement) -> &'a [Event] {
    &events[p.range.0..p.range.1]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("node{i}")).collect()
    }

    #[test]
    fn ranges_partition_exactly() {
        for (n, epb) in [(1000, 128), (1, 10), (777, 100), (0, 5)] {
            let rs = split_ranges(n, epb);
            let mut covered = 0;
            for (i, (s, e)) in rs.iter().enumerate() {
                assert_eq!(*s, covered);
                assert!(*e > *s || n == 0);
                covered = *e;
                if i < rs.len() - 1 {
                    assert_eq!(e - s, epb);
                }
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn placement_deterministic() {
        let ns = nodes(8);
        let a = placement_nodes(BrickId::new(1, 5), &ns, 3);
        let b = placement_nodes(BrickId::new(1, 5), &ns, 3);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        // replicas are distinct nodes
        let mut u = a.clone();
        u.dedup();
        assert_eq!(u.len(), 3);
    }

    #[test]
    fn placement_spreads_load() {
        let ns = nodes(4);
        let mut counts = std::collections::HashMap::new();
        for seq in 0..400 {
            let p = placement_nodes(BrickId::new(1, seq), &ns, 1);
            *counts.entry(p[0].clone()).or_insert(0usize) += 1;
        }
        for n in &ns {
            let c = counts.get(n).copied().unwrap_or(0);
            assert!((60..=140).contains(&c), "{n}: {c}");
        }
    }

    #[test]
    fn adding_node_moves_few_bricks() {
        let ns4 = nodes(4);
        let ns5 = nodes(5);
        let moved = (0..1000)
            .filter(|&seq| {
                placement_nodes(BrickId::new(1, seq), &ns4, 1)
                    != placement_nodes(BrickId::new(1, seq), &ns5, 1)
            })
            .count();
        // rendezvous hashing: expect ~1/5 moved, certainly < 1/3
        assert!(moved < 334, "moved {moved}");
    }

    #[test]
    fn replication_capped_by_cluster_size() {
        let ns = nodes(2);
        let p = placement_nodes(BrickId::new(1, 0), &ns, 5);
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn split_events_end_to_end() {
        let cfg = SplitConfig { dataset: 3, events_per_brick: 100, replication: 2 };
        let ps = split_events(&cfg, 250, &nodes(4));
        assert_eq!(ps.len(), 3);
        assert_eq!(ps[2].range, (200, 250));
        for p in &ps {
            assert_eq!(p.holders.len(), 2);
            assert_eq!(p.id.dataset, 3);
        }
    }
}
