//! Brick file format — the ROOT-tree analogue (§4.1: "the Root tree class
//! is optimized to reduce storage space usage and enhance accession
//! speed"). A brick is a paged, checksummed, optionally-compressed
//! container of serialized events:
//!
//! ```text
//! [header]     magic "GEPSBRK1" | version u16 (1 | 2) | codec u8 |
//!              reserved u8 | dataset u32 | seq u32 | n_events u64 |
//!              n_pages u32
//! [page]*      n_events u32 | raw_len u32 | stored_len u32 |
//!              xxhash64(stored bytes) u64 | stored bytes
//! [trailer]    xxhash64 of everything before the trailer
//! ```
//!
//! The *page payload* (the raw bytes before optional compression) comes
//! in two layouts, selected by the header version:
//!
//! **v1 — row-wise** (migration format): events serialized one after
//! another, each as `id u64 | n_tracks u16 | n_vertices u16 | signal u8`
//! followed by its track and vertex records.
//!
//! **v2 — columnar (SoA)**: one flat array per field, so a page decodes
//! straight into kernel-ready [`ColumnarEvents`] buffers with zero
//! per-event allocation:
//!
//! ```text
//! n_tracks u32 | n_verts u32            (page column lengths)
//! ids          u64 × n_events
//! signal       u8  × n_events
//! track_count  u16 × n_events           (prefix-summed into offsets)
//! vert_count   u16 × n_events
//! e, px, py, pz        f32 × n_tracks   (one array per component)
//! track_vertex         u16 × n_tracks
//! vx, vy, vz           f32 × n_verts
//! vert_ntracks         u16 × n_verts
//! ```
//!
//! **Version negotiation:** readers accept both versions ([`decode`] and
//! [`decode_columnar`] dispatch on the header); writers emit v2
//! ([`BrickFile::encode_columnar`] — the cluster authoring and node
//! result paths) while [`BrickFile::encode`] keeps producing v1 for
//! migration and format tests. Both decode paths yield bit-identical
//! events, batches, and therefore histograms.
//!
//! Every page is independently decodable (so nodes can stream-filter
//! without loading whole bricks) and every page carries its own checksum —
//! corruption is detected, which the replication layer (`replica`) turns
//! into failover instead of wrong answers.
//!
//! [`decode`]: BrickFile::decode
//! [`decode_columnar`]: BrickFile::decode_columnar

use crate::brick::codec;
use crate::brick::columnar::ColumnarEvents;
use crate::brick::BrickId;
use crate::events::model::Event;
use crate::util::xxhash64;
use std::borrow::Cow;

const MAGIC: &[u8; 8] = b"GEPSBRK1";
/// Row-wise page payloads (the 2003-style serialization).
pub const VERSION_V1: u16 = 1;
/// Columnar (SoA) page payloads — the hot-path format.
pub const VERSION_V2: u16 = 2;
const HASH_SEED: u64 = 0x6765_7073; // "geps"

/// Per-page codec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    Raw = 0,
    Lzss = 1,
}

impl Codec {
    fn from_u8(v: u8) -> Option<Codec> {
        match v {
            0 => Some(Codec::Raw),
            1 => Some(Codec::Lzss),
            _ => None,
        }
    }
}

/// Decoded brick metadata (header fields).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BrickMeta {
    pub id: BrickId,
    /// Page payload layout: [`VERSION_V1`] (row-wise) or [`VERSION_V2`]
    /// (columnar).
    pub version: u16,
    pub codec: Codec,
    pub n_events: u64,
    pub n_pages: u32,
}

/// An encoded brick: bytes plus its metadata.
#[derive(Debug, Clone)]
pub struct BrickFile {
    pub meta: BrickMeta,
    pub bytes: Vec<u8>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BrickError {
    BadMagic,
    BadVersion(u16),
    BadCodec(u8),
    Truncated,
    ChecksumMismatch { page: Option<u32> },
    Corrupt(&'static str),
}

impl std::fmt::Display for BrickError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BrickError::BadMagic => write!(f, "bad magic"),
            BrickError::BadVersion(v) => write!(f, "bad version {v}"),
            BrickError::BadCodec(c) => write!(f, "bad codec {c}"),
            BrickError::Truncated => write!(f, "truncated brick"),
            BrickError::ChecksumMismatch { page: Some(p) } => {
                write!(f, "checksum mismatch in page {p}")
            }
            BrickError::ChecksumMismatch { page: None } => {
                write!(f, "trailer checksum mismatch")
            }
            BrickError::Corrupt(m) => write!(f, "corrupt brick: {m}"),
        }
    }
}
impl std::error::Error for BrickError {}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], BrickError> {
        if self.i + n > self.b.len() {
            return Err(BrickError::Truncated);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16, BrickError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, BrickError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, BrickError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, BrickError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u8(&mut self) -> Result<u8, BrickError> {
        Ok(self.take(1)?[0])
    }

    /// Bulk-read `n` little-endian f32s into a column buffer.
    fn f32_col(&mut self, n: usize, out: &mut Vec<f32>) -> Result<(), BrickError> {
        let b = self.take(n * 4)?;
        out.reserve(n);
        for c in b.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(())
    }

    /// Bulk-read `n` little-endian u16s into a column buffer.
    fn u16_col(&mut self, n: usize, out: &mut Vec<u16>) -> Result<(), BrickError> {
        let b = self.take(n * 2)?;
        out.reserve(n);
        for c in b.chunks_exact(2) {
            out.push(u16::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(())
    }

    /// Bulk-read `n` little-endian u64s into a column buffer.
    fn u64_col(&mut self, n: usize, out: &mut Vec<u64>) -> Result<(), BrickError> {
        let b = self.take(n * 8)?;
        out.reserve(n);
        for c in b.chunks_exact(8) {
            out.push(u64::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(())
    }
}

/// v1 row-wise event serialization.
fn encode_event(out: &mut Vec<u8>, ev: &Event) {
    // same fail-fast as the v2 writer: a wrapped count would serialize
    // all the records but only be discovered at decode time
    assert!(
        ev.tracks.len() <= u16::MAX as usize,
        "event {}: {} tracks exceed the u16 brick limit",
        ev.id,
        ev.tracks.len()
    );
    assert!(
        ev.vertices.len() <= u16::MAX as usize,
        "event {}: {} vertices exceed the u16 brick limit",
        ev.id,
        ev.vertices.len()
    );
    put_u64(out, ev.id);
    put_u16(out, ev.tracks.len() as u16);
    put_u16(out, ev.vertices.len() as u16);
    out.push(ev.is_signal as u8);
    for t in &ev.tracks {
        put_f32(out, t.e);
        put_f32(out, t.px);
        put_f32(out, t.py);
        put_f32(out, t.pz);
        put_u16(out, t.vertex);
    }
    for v in &ev.vertices {
        put_f32(out, v.x);
        put_f32(out, v.y);
        put_f32(out, v.z);
        put_u16(out, v.n_tracks);
    }
}

/// v1 row-wise event deserialization, appended straight into columns
/// (even the migration path never builds per-event `Vec`s).
fn decode_event_columnar(
    r: &mut Reader,
    cols: &mut ColumnarEvents,
) -> Result<(), BrickError> {
    let id = r.u64()?;
    let nt = r.u16()? as usize;
    let nv = r.u16()? as usize;
    let is_signal = r.u8()?;
    cols.ids.push(id);
    cols.signal.push((is_signal != 0) as u8);
    for _ in 0..nt {
        cols.e.push(r.f32()?);
        cols.px.push(r.f32()?);
        cols.py.push(r.f32()?);
        cols.pz.push(r.f32()?);
        cols.track_vertex.push(r.u16()?);
    }
    cols.track_off.push(cols.e.len() as u32);
    for _ in 0..nv {
        cols.vx.push(r.f32()?);
        cols.vy.push(r.f32()?);
        cols.vz.push(r.f32()?);
        cols.vert_ntracks.push(r.u16()?);
    }
    cols.vert_off.push(cols.vx.len() as u32);
    Ok(())
}

/// v2 columnar page payload serialization (events `a..b` of `cols`).
fn encode_page_v2(out: &mut Vec<u8>, cols: &ColumnarEvents, a: usize, b: usize) {
    let ta = cols.track_off[a] as usize;
    let tb = cols.track_off[b] as usize;
    let va = cols.vert_off[a] as usize;
    let vb = cols.vert_off[b] as usize;
    put_u32(out, (tb - ta) as u32);
    put_u32(out, (vb - va) as u32);
    for &id in &cols.ids[a..b] {
        put_u64(out, id);
    }
    out.extend_from_slice(&cols.signal[a..b]);
    for i in a..b {
        let nt = cols.track_off[i + 1] - cols.track_off[i];
        // fail fast at authoring time: a silently wrapped count would
        // only surface as Corrupt("track counts") at some later reader
        assert!(nt <= u16::MAX as u32, "event {i}: {nt} tracks exceed the u16 brick limit");
        put_u16(out, nt as u16);
    }
    for i in a..b {
        let nv = cols.vert_off[i + 1] - cols.vert_off[i];
        assert!(nv <= u16::MAX as u32, "event {i}: {nv} vertices exceed the u16 brick limit");
        put_u16(out, nv as u16);
    }
    for &v in &cols.e[ta..tb] {
        put_f32(out, v);
    }
    for &v in &cols.px[ta..tb] {
        put_f32(out, v);
    }
    for &v in &cols.py[ta..tb] {
        put_f32(out, v);
    }
    for &v in &cols.pz[ta..tb] {
        put_f32(out, v);
    }
    for &v in &cols.track_vertex[ta..tb] {
        put_u16(out, v);
    }
    for &v in &cols.vx[va..vb] {
        put_f32(out, v);
    }
    for &v in &cols.vy[va..vb] {
        put_f32(out, v);
    }
    for &v in &cols.vz[va..vb] {
        put_f32(out, v);
    }
    for &v in &cols.vert_ntracks[va..vb] {
        put_u16(out, v);
    }
}

/// v2 columnar page payload deserialization: bulk column reads appended
/// onto `cols`, with counts prefix-summed into the offset tables.
fn decode_page_v2(
    r: &mut Reader,
    n_ev: usize,
    cols: &mut ColumnarEvents,
) -> Result<(), BrickError> {
    let n_tracks = r.u32()? as usize;
    let n_verts = r.u32()? as usize;
    r.u64_col(n_ev, &mut cols.ids)?;
    cols.signal.extend_from_slice(r.take(n_ev)?);
    // counts → absolute offsets (accumulated in usize so hostile counts
    // cannot overflow the u32 offsets undetected)
    let track_base = cols.e.len();
    let counts = r.take(n_ev * 2)?;
    let mut acc = track_base;
    cols.track_off.reserve(n_ev);
    for c in counts.chunks_exact(2) {
        acc += u16::from_le_bytes(c.try_into().unwrap()) as usize;
        if acc > u32::MAX as usize {
            return Err(BrickError::Corrupt("track counts"));
        }
        cols.track_off.push(acc as u32);
    }
    if acc - track_base != n_tracks {
        return Err(BrickError::Corrupt("track counts"));
    }
    let vert_base = cols.vx.len();
    let counts = r.take(n_ev * 2)?;
    let mut acc = vert_base;
    cols.vert_off.reserve(n_ev);
    for c in counts.chunks_exact(2) {
        acc += u16::from_le_bytes(c.try_into().unwrap()) as usize;
        if acc > u32::MAX as usize {
            return Err(BrickError::Corrupt("vertex counts"));
        }
        cols.vert_off.push(acc as u32);
    }
    if acc - vert_base != n_verts {
        return Err(BrickError::Corrupt("vertex counts"));
    }
    r.f32_col(n_tracks, &mut cols.e)?;
    r.f32_col(n_tracks, &mut cols.px)?;
    r.f32_col(n_tracks, &mut cols.py)?;
    r.f32_col(n_tracks, &mut cols.pz)?;
    r.u16_col(n_tracks, &mut cols.track_vertex)?;
    r.f32_col(n_verts, &mut cols.vx)?;
    r.f32_col(n_verts, &mut cols.vy)?;
    r.f32_col(n_verts, &mut cols.vz)?;
    r.u16_col(n_verts, &mut cols.vert_ntracks)?;
    Ok(())
}

/// Serialize one page: header + (optionally compressed) payload. Shared
/// by both brick versions — the compression decision and the "stored
/// raw despite Lzss codec" flag live only here.
fn write_page(out: &mut Vec<u8>, n_ev: usize, raw: &[u8], codec_kind: Codec) {
    let (stored, stored_raw): (Cow<[u8]>, bool) = match codec_kind {
        Codec::Raw => (Cow::Borrowed(raw), false),
        Codec::Lzss => {
            let c = codec::compress(raw);
            // store raw if compression didn't help
            if c.len() < raw.len() {
                (Cow::Owned(c), false)
            } else {
                (Cow::Borrowed(raw), true)
            }
        }
    };
    put_u32(out, n_ev as u32);
    put_u32(out, raw.len() as u32);
    // high bit of stored_len marks "stored raw despite Lzss codec"
    let mut stored_len = stored.len() as u32;
    if stored_raw {
        stored_len |= 0x8000_0000;
    }
    put_u32(out, stored_len);
    put_u64(out, xxhash64(&stored, HASH_SEED));
    out.extend_from_slice(&stored);
}

/// Read one page header + payload, verifying its checksum and inflating
/// the payload. Borrows from the brick bytes when the page is stored raw.
fn read_page<'a>(
    r: &mut Reader<'a>,
    codec_kind: Codec,
    page_idx: u32,
) -> Result<(usize, Cow<'a, [u8]>), BrickError> {
    let n_ev = r.u32()? as usize;
    let raw_len = r.u32()? as usize;
    let stored_len_field = r.u32()?;
    let stored_raw = stored_len_field & 0x8000_0000 != 0;
    let stored_len = (stored_len_field & 0x7fff_ffff) as usize;
    let checksum = r.u64()?;
    let stored = r.take(stored_len)?;
    if xxhash64(stored, HASH_SEED) != checksum {
        return Err(BrickError::ChecksumMismatch { page: Some(page_idx) });
    }
    let raw: Cow<[u8]> = match (codec_kind, stored_raw) {
        (Codec::Raw, _) | (Codec::Lzss, true) => Cow::Borrowed(stored),
        (Codec::Lzss, false) => Cow::Owned(
            codec::decompress(stored, raw_len)
                .ok_or(BrickError::Corrupt("lzss stream"))?,
        ),
    };
    if raw.len() != raw_len {
        return Err(BrickError::Corrupt("raw length"));
    }
    Ok((n_ev, raw))
}

fn put_header(out: &mut Vec<u8>, id: BrickId, version: u16, codec_kind: Codec, n_events: u64, n_pages: u32) {
    out.extend_from_slice(MAGIC);
    put_u16(out, version);
    out.push(codec_kind as u8);
    out.push(0); // reserved
    put_u32(out, id.dataset);
    put_u32(out, id.seq);
    put_u64(out, n_events);
    put_u32(out, n_pages);
}

impl BrickFile {
    /// Encode events into a **v1 row-wise** brick. `events_per_page`
    /// controls streaming granularity (pages decode independently).
    /// Kept for migration — new bricks should use [`encode_columnar`].
    ///
    /// [`encode_columnar`]: BrickFile::encode_columnar
    pub fn encode(
        id: BrickId,
        events: &[Event],
        codec_kind: Codec,
        events_per_page: usize,
    ) -> BrickFile {
        let epp = events_per_page.max(1);
        let pages: Vec<&[Event]> = events.chunks(epp).collect();

        let mut out = Vec::new();
        put_header(
            &mut out,
            id,
            VERSION_V1,
            codec_kind,
            events.len() as u64,
            pages.len() as u32,
        );

        let mut raw = Vec::new();
        for page in &pages {
            raw.clear();
            for ev in *page {
                encode_event(&mut raw, ev);
            }
            write_page(&mut out, page.len(), &raw, codec_kind);
        }
        let trailer = xxhash64(&out, HASH_SEED);
        put_u64(&mut out, trailer);

        BrickFile {
            meta: BrickMeta {
                id,
                version: VERSION_V1,
                codec: codec_kind,
                n_events: events.len() as u64,
                n_pages: pages.len() as u32,
            },
            bytes: out,
        }
    }

    /// Encode a column set into a **v2 columnar** brick — the default
    /// writer path (cluster dataset authoring, node result bricks).
    /// Events with more than `u16::MAX` tracks or vertices are not
    /// representable (same limit as v1's row-wise counts); encoding
    /// panics rather than write a brick that cannot decode.
    pub fn encode_columnar(
        id: BrickId,
        cols: &ColumnarEvents,
        codec_kind: Codec,
        events_per_page: usize,
    ) -> BrickFile {
        let epp = events_per_page.max(1);
        let n = cols.len();
        let n_pages = n.div_ceil(epp);

        let mut out = Vec::new();
        put_header(
            &mut out,
            id,
            VERSION_V2,
            codec_kind,
            n as u64,
            n_pages as u32,
        );

        let mut raw = Vec::new();
        let mut start = 0;
        while start < n {
            let end = (start + epp).min(n);
            raw.clear();
            encode_page_v2(&mut raw, cols, start, end);
            write_page(&mut out, end - start, &raw, codec_kind);
            start = end;
        }
        let trailer = xxhash64(&out, HASH_SEED);
        put_u64(&mut out, trailer);

        BrickFile {
            meta: BrickMeta {
                id,
                version: VERSION_V2,
                codec: codec_kind,
                n_events: n as u64,
                n_pages: n_pages as u32,
            },
            bytes: out,
        }
    }

    /// Validate + decode header only (cheap).
    pub fn decode_meta(bytes: &[u8]) -> Result<BrickMeta, BrickError> {
        let mut r = Reader { b: bytes, i: 0 };
        if r.take(8)? != MAGIC {
            return Err(BrickError::BadMagic);
        }
        let ver = r.u16()?;
        if ver != VERSION_V1 && ver != VERSION_V2 {
            return Err(BrickError::BadVersion(ver));
        }
        let codec_byte = r.u8()?;
        let codec =
            Codec::from_u8(codec_byte).ok_or(BrickError::BadCodec(codec_byte))?;
        let _reserved = r.u8()?;
        let dataset = r.u32()?;
        let seq = r.u32()?;
        let n_events = r.u64()?;
        let n_pages = r.u32()?;
        Ok(BrickMeta {
            id: BrickId::new(dataset, seq),
            version: ver,
            codec,
            n_events,
            n_pages,
        })
    }

    /// Full decode with checksum verification, directly into column
    /// buffers — the node hot path. Handles both brick versions (v1
    /// events are transposed on the fly; v2 pages are bulk column reads
    /// with zero per-event work).
    pub fn decode_columnar(
        bytes: &[u8],
    ) -> Result<(BrickMeta, ColumnarEvents), BrickError> {
        if bytes.len() < 8 {
            return Err(BrickError::Truncated);
        }
        // trailer check first: whole-file integrity
        let body_len = bytes.len() - 8;
        let trailer =
            u64::from_le_bytes(bytes[body_len..].try_into().unwrap());
        if xxhash64(&bytes[..body_len], HASH_SEED) != trailer {
            return Err(BrickError::ChecksumMismatch { page: None });
        }

        let meta = Self::decode_meta(bytes)?;
        let mut r = Reader { b: &bytes[..body_len], i: 32 };
        let mut cols =
            ColumnarEvents::with_capacity(meta.n_events as usize, 0, 0);
        for page_idx in 0..meta.n_pages {
            let (n_ev, raw) = read_page(&mut r, meta.codec, page_idx)?;
            let mut pr = Reader { b: &raw, i: 0 };
            if meta.version == VERSION_V1 {
                for _ in 0..n_ev {
                    decode_event_columnar(&mut pr, &mut cols)?;
                }
            } else {
                decode_page_v2(&mut pr, n_ev, &mut cols)?;
            }
            if pr.i != raw.len() {
                return Err(BrickError::Corrupt("page trailing bytes"));
            }
        }
        if cols.len() as u64 != meta.n_events {
            return Err(BrickError::Corrupt("event count"));
        }
        Ok((meta, cols))
    }

    /// Full decode with checksum verification, materializing row-wise
    /// `Event`s (tests, tooling, migration — NOT the node hot path).
    pub fn decode(bytes: &[u8]) -> Result<(BrickMeta, Vec<Event>), BrickError> {
        let (meta, cols) = Self::decode_columnar(bytes)?;
        Ok((meta, cols.to_events()))
    }

    pub fn size(&self) -> usize {
        self.bytes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::generator::{EventGenerator, GeneratorConfig};

    fn gen(n: usize, seed: u64) -> Vec<Event> {
        EventGenerator::new(GeneratorConfig::default(), seed).take(n)
    }

    #[test]
    fn roundtrip_raw() {
        let evs = gen(100, 1);
        let brick =
            BrickFile::encode(BrickId::new(1, 0), &evs, Codec::Raw, 32);
        let (meta, decoded) = BrickFile::decode(&brick.bytes).unwrap();
        assert_eq!(meta.version, VERSION_V1);
        assert_eq!(meta.n_events, 100);
        assert_eq!(meta.n_pages, 4);
        assert_eq!(decoded, evs);
    }

    #[test]
    fn roundtrip_lzss() {
        let evs = gen(200, 2);
        let brick =
            BrickFile::encode(BrickId::new(2, 7), &evs, Codec::Lzss, 50);
        let (meta, decoded) = BrickFile::decode(&brick.bytes).unwrap();
        assert_eq!(meta.id, BrickId::new(2, 7));
        assert_eq!(decoded, evs);
    }

    #[test]
    fn roundtrip_columnar_v2() {
        let evs = gen(150, 9);
        let cols = ColumnarEvents::from_events(&evs);
        for codec_kind in [Codec::Raw, Codec::Lzss] {
            let brick = BrickFile::encode_columnar(
                BrickId::new(4, 2),
                &cols,
                codec_kind,
                48,
            );
            let meta = BrickFile::decode_meta(&brick.bytes).unwrap();
            assert_eq!(meta.version, VERSION_V2);
            assert_eq!(meta.n_events, 150);
            assert_eq!(meta.n_pages, 4); // ceil(150/48)
            let (m2, decoded_cols) =
                BrickFile::decode_columnar(&brick.bytes).unwrap();
            assert_eq!(m2, meta);
            assert_eq!(decoded_cols, cols);
            // row-wise view agrees too
            let (_, decoded_rows) = BrickFile::decode(&brick.bytes).unwrap();
            assert_eq!(decoded_rows, evs);
        }
    }

    #[test]
    fn v1_and_v2_decode_to_identical_columns() {
        let evs = gen(300, 10);
        let cols = ColumnarEvents::from_events(&evs);
        let v1 = BrickFile::encode(BrickId::new(5, 5), &evs, Codec::Lzss, 64);
        let v2 = BrickFile::encode_columnar(
            BrickId::new(5, 5),
            &cols,
            Codec::Lzss,
            64,
        );
        let (_, c1) = BrickFile::decode_columnar(&v1.bytes).unwrap();
        let (_, c2) = BrickFile::decode_columnar(&v2.bytes).unwrap();
        assert_eq!(c1, c2);
    }

    #[test]
    fn columnar_bricks_are_no_larger() {
        // SoA grouping puts similar bytes together, so LZSS should do at
        // least as well as on the interleaved row-wise layout (the §4.1
        // "reduce storage space usage" claim, carried to v2).
        let evs = gen(500, 11);
        let cols = ColumnarEvents::from_events(&evs);
        let v1 = BrickFile::encode(BrickId::new(6, 0), &evs, Codec::Lzss, 128);
        let v2 = BrickFile::encode_columnar(
            BrickId::new(6, 0),
            &cols,
            Codec::Lzss,
            128,
        );
        // allow a small tolerance: the column layout adds two u32 lengths
        // per page and changes match structure
        assert!(
            (v2.size() as f64) < v1.size() as f64 * 1.05,
            "v2 {} vs v1 {}",
            v2.size(),
            v1.size()
        );
    }

    #[test]
    fn empty_brick() {
        let brick = BrickFile::encode(BrickId::new(0, 0), &[], Codec::Raw, 16);
        let (meta, decoded) = BrickFile::decode(&brick.bytes).unwrap();
        assert_eq!(meta.n_events, 0);
        assert!(decoded.is_empty());
        let empty = ColumnarEvents::new();
        let v2 = BrickFile::encode_columnar(
            BrickId::new(0, 0),
            &empty,
            Codec::Lzss,
            16,
        );
        let (meta, cols) = BrickFile::decode_columnar(&v2.bytes).unwrap();
        assert_eq!(meta.n_events, 0);
        assert_eq!(meta.n_pages, 0);
        assert!(cols.is_empty());
    }

    #[test]
    fn meta_only_decode() {
        let evs = gen(10, 3);
        let brick =
            BrickFile::encode(BrickId::new(5, 9), &evs, Codec::Lzss, 4);
        let meta = BrickFile::decode_meta(&brick.bytes).unwrap();
        assert_eq!(meta.id, BrickId::new(5, 9));
        assert_eq!(meta.n_events, 10);
        assert_eq!(meta.n_pages, 3);
    }

    #[test]
    fn bad_magic_rejected() {
        let evs = gen(5, 4);
        let mut brick =
            BrickFile::encode(BrickId::new(1, 1), &evs, Codec::Raw, 8);
        brick.bytes[0] = b'X';
        assert_eq!(
            BrickFile::decode(&brick.bytes).unwrap_err(),
            // trailer covers header too, so whole-file checksum trips first
            BrickError::ChecksumMismatch { page: None }
        );
        assert_eq!(
            BrickFile::decode_meta(&brick.bytes).unwrap_err(),
            BrickError::BadMagic
        );
    }

    #[test]
    fn unknown_version_rejected() {
        let evs = gen(5, 12);
        let mut brick =
            BrickFile::encode(BrickId::new(1, 1), &evs, Codec::Raw, 8);
        brick.bytes[8] = 9; // version LE low byte
        assert_eq!(
            BrickFile::decode_meta(&brick.bytes).unwrap_err(),
            BrickError::BadVersion(9)
        );
    }

    #[test]
    fn payload_corruption_detected() {
        let evs = gen(50, 5);
        let mut brick =
            BrickFile::encode(BrickId::new(1, 2), &evs, Codec::Raw, 16);
        let mid = brick.bytes.len() / 2;
        brick.bytes[mid] ^= 0xff;
        assert!(matches!(
            BrickFile::decode(&brick.bytes).unwrap_err(),
            BrickError::ChecksumMismatch { .. }
        ));
    }

    #[test]
    fn truncation_detected() {
        let evs = gen(20, 6);
        let brick =
            BrickFile::encode(BrickId::new(1, 3), &evs, Codec::Raw, 8);
        for cut in [3usize, 20, brick.bytes.len() - 1] {
            assert!(BrickFile::decode(&brick.bytes[..cut]).is_err());
        }
        let cols = ColumnarEvents::from_events(&gen(20, 6));
        let v2 =
            BrickFile::encode_columnar(BrickId::new(1, 3), &cols, Codec::Raw, 8);
        for cut in [3usize, 20, v2.bytes.len() - 1] {
            assert!(BrickFile::decode_columnar(&v2.bytes[..cut]).is_err());
        }
    }

    #[test]
    fn compression_helps_on_real_events() {
        let evs = gen(500, 7);
        let raw = BrickFile::encode(BrickId::new(1, 4), &evs, Codec::Raw, 64);
        let lz = BrickFile::encode(BrickId::new(1, 4), &evs, Codec::Lzss, 64);
        assert!(lz.size() <= raw.size());
    }

    #[test]
    fn signal_flag_roundtrips() {
        let cfg = GeneratorConfig { signal_fraction: 0.5, ..Default::default() };
        let evs = EventGenerator::new(cfg, 8).take(64);
        let brick =
            BrickFile::encode(BrickId::new(3, 0), &evs, Codec::Lzss, 16);
        let (_, decoded) = BrickFile::decode(&brick.bytes).unwrap();
        for (a, b) in evs.iter().zip(&decoded) {
            assert_eq!(a.is_signal, b.is_signal);
        }
    }
}
