//! Brick file format — the ROOT-tree analogue (§4.1: "the Root tree class
//! is optimized to reduce storage space usage and enhance accession
//! speed"). A brick is a paged, checksummed, optionally-compressed
//! container of serialized events:
//!
//! ```text
//! [header]     magic "GEPSBRK1" | version u16 | codec u8 | reserved u8
//!              dataset u32 | seq u32 | n_events u64 | n_pages u32
//! [page]*      n_events u32 | raw_len u32 | stored_len u32 |
//!              xxhash64(stored bytes) u64 | stored bytes
//! [trailer]    xxhash64 of everything before the trailer
//! ```
//!
//! Every page is independently decodable (so nodes can stream-filter
//! without loading whole bricks) and every page carries its own checksum —
//! corruption is detected, which the replication layer (`replica`) turns
//! into failover instead of wrong answers.

use crate::brick::codec;
use crate::brick::BrickId;
use crate::events::model::{Event, Track, Vertex};
use crate::util::xxhash64;

const MAGIC: &[u8; 8] = b"GEPSBRK1";
const VERSION: u16 = 1;
const HASH_SEED: u64 = 0x6765_7073; // "geps"

/// Per-page codec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    Raw = 0,
    Lzss = 1,
}

impl Codec {
    fn from_u8(v: u8) -> Option<Codec> {
        match v {
            0 => Some(Codec::Raw),
            1 => Some(Codec::Lzss),
            _ => None,
        }
    }
}

/// Decoded brick metadata (header fields).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BrickMeta {
    pub id: BrickId,
    pub codec: Codec,
    pub n_events: u64,
    pub n_pages: u32,
}

/// An encoded brick: bytes plus its metadata.
#[derive(Debug, Clone)]
pub struct BrickFile {
    pub meta: BrickMeta,
    pub bytes: Vec<u8>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BrickError {
    BadMagic,
    BadVersion(u16),
    BadCodec(u8),
    Truncated,
    ChecksumMismatch { page: Option<u32> },
    Corrupt(&'static str),
}

impl std::fmt::Display for BrickError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BrickError::BadMagic => write!(f, "bad magic"),
            BrickError::BadVersion(v) => write!(f, "bad version {v}"),
            BrickError::BadCodec(c) => write!(f, "bad codec {c}"),
            BrickError::Truncated => write!(f, "truncated brick"),
            BrickError::ChecksumMismatch { page: Some(p) } => {
                write!(f, "checksum mismatch in page {p}")
            }
            BrickError::ChecksumMismatch { page: None } => {
                write!(f, "trailer checksum mismatch")
            }
            BrickError::Corrupt(m) => write!(f, "corrupt brick: {m}"),
        }
    }
}
impl std::error::Error for BrickError {}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], BrickError> {
        if self.i + n > self.b.len() {
            return Err(BrickError::Truncated);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16, BrickError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, BrickError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, BrickError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, BrickError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u8(&mut self) -> Result<u8, BrickError> {
        Ok(self.take(1)?[0])
    }
}

fn encode_event(out: &mut Vec<u8>, ev: &Event) {
    put_u64(out, ev.id);
    put_u16(out, ev.tracks.len() as u16);
    put_u16(out, ev.vertices.len() as u16);
    out.push(ev.is_signal as u8);
    for t in &ev.tracks {
        put_f32(out, t.e);
        put_f32(out, t.px);
        put_f32(out, t.py);
        put_f32(out, t.pz);
        put_u16(out, t.vertex);
    }
    for v in &ev.vertices {
        put_f32(out, v.x);
        put_f32(out, v.y);
        put_f32(out, v.z);
        put_u16(out, v.n_tracks);
    }
}

fn decode_event(r: &mut Reader) -> Result<Event, BrickError> {
    let id = r.u64()?;
    let nt = r.u16()? as usize;
    let nv = r.u16()? as usize;
    let is_signal = r.u8()? != 0;
    let mut tracks = Vec::with_capacity(nt);
    for _ in 0..nt {
        let e = r.f32()?;
        let px = r.f32()?;
        let py = r.f32()?;
        let pz = r.f32()?;
        let vertex = r.u16()?;
        tracks.push(Track { e, px, py, pz, vertex });
    }
    let mut vertices = Vec::with_capacity(nv);
    for _ in 0..nv {
        vertices.push(Vertex {
            x: r.f32()?,
            y: r.f32()?,
            z: r.f32()?,
            n_tracks: r.u16()?,
        });
    }
    Ok(Event { id, tracks, vertices, is_signal })
}

impl BrickFile {
    /// Encode events into a brick. `events_per_page` controls streaming
    /// granularity (pages decode independently).
    pub fn encode(
        id: BrickId,
        events: &[Event],
        codec_kind: Codec,
        events_per_page: usize,
    ) -> BrickFile {
        let epp = events_per_page.max(1);
        let pages: Vec<&[Event]> = events.chunks(epp).collect();

        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        put_u16(&mut out, VERSION);
        out.push(codec_kind as u8);
        out.push(0); // reserved
        put_u32(&mut out, id.dataset);
        put_u32(&mut out, id.seq);
        put_u64(&mut out, events.len() as u64);
        put_u32(&mut out, pages.len() as u32);

        for page in &pages {
            let mut raw = Vec::new();
            for ev in *page {
                encode_event(&mut raw, ev);
            }
            let stored = match codec_kind {
                Codec::Raw => raw.clone(),
                Codec::Lzss => {
                    let c = codec::compress(&raw);
                    // store raw if compression didn't help
                    if c.len() < raw.len() {
                        c
                    } else {
                        raw.clone()
                    }
                }
            };
            let effective_raw = stored.len() == raw.len() && stored == raw;
            put_u32(&mut out, page.len() as u32);
            put_u32(&mut out, raw.len() as u32);
            // high bit of stored_len marks "stored raw despite Lzss codec"
            let mut stored_len = stored.len() as u32;
            if codec_kind == Codec::Lzss && effective_raw {
                stored_len |= 0x8000_0000;
            }
            put_u32(&mut out, stored_len);
            put_u64(&mut out, xxhash64(&stored, HASH_SEED));
            out.extend_from_slice(&stored);
        }
        let trailer = xxhash64(&out, HASH_SEED);
        put_u64(&mut out, trailer);

        BrickFile {
            meta: BrickMeta {
                id,
                codec: codec_kind,
                n_events: events.len() as u64,
                n_pages: pages.len() as u32,
            },
            bytes: out,
        }
    }

    /// Validate + decode header only (cheap).
    pub fn decode_meta(bytes: &[u8]) -> Result<BrickMeta, BrickError> {
        let mut r = Reader { b: bytes, i: 0 };
        if r.take(8)? != MAGIC {
            return Err(BrickError::BadMagic);
        }
        let ver = r.u16()?;
        if ver != VERSION {
            return Err(BrickError::BadVersion(ver));
        }
        let codec_byte = r.u8()?;
        let codec =
            Codec::from_u8(codec_byte).ok_or(BrickError::BadCodec(codec_byte))?;
        let _reserved = r.u8()?;
        let dataset = r.u32()?;
        let seq = r.u32()?;
        let n_events = r.u64()?;
        let n_pages = r.u32()?;
        Ok(BrickMeta {
            id: BrickId::new(dataset, seq),
            codec,
            n_events,
            n_pages,
        })
    }

    /// Full decode with checksum verification.
    pub fn decode(bytes: &[u8]) -> Result<(BrickMeta, Vec<Event>), BrickError> {
        if bytes.len() < 8 {
            return Err(BrickError::Truncated);
        }
        // trailer check first: whole-file integrity
        let body_len = bytes.len() - 8;
        let trailer =
            u64::from_le_bytes(bytes[body_len..].try_into().unwrap());
        if xxhash64(&bytes[..body_len], HASH_SEED) != trailer {
            return Err(BrickError::ChecksumMismatch { page: None });
        }

        let meta = Self::decode_meta(bytes)?;
        let mut r = Reader { b: &bytes[..body_len], i: 32 };
        let mut events = Vec::with_capacity(meta.n_events as usize);
        for page_idx in 0..meta.n_pages {
            let n_ev = r.u32()? as usize;
            let raw_len = r.u32()? as usize;
            let stored_len_field = r.u32()?;
            let stored_raw = stored_len_field & 0x8000_0000 != 0;
            let stored_len = (stored_len_field & 0x7fff_ffff) as usize;
            let checksum = r.u64()?;
            let stored = r.take(stored_len)?;
            if xxhash64(stored, HASH_SEED) != checksum {
                return Err(BrickError::ChecksumMismatch {
                    page: Some(page_idx),
                });
            }
            let raw: Vec<u8> = match (meta.codec, stored_raw) {
                (Codec::Raw, _) | (Codec::Lzss, true) => stored.to_vec(),
                (Codec::Lzss, false) => codec::decompress(stored, raw_len)
                    .ok_or(BrickError::Corrupt("lzss stream"))?,
            };
            if raw.len() != raw_len {
                return Err(BrickError::Corrupt("raw length"));
            }
            let mut pr = Reader { b: &raw, i: 0 };
            for _ in 0..n_ev {
                events.push(decode_event(&mut pr)?);
            }
            if pr.i != raw.len() {
                return Err(BrickError::Corrupt("page trailing bytes"));
            }
        }
        if events.len() as u64 != meta.n_events {
            return Err(BrickError::Corrupt("event count"));
        }
        Ok((meta, events))
    }

    pub fn size(&self) -> usize {
        self.bytes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::generator::{EventGenerator, GeneratorConfig};

    fn gen(n: usize, seed: u64) -> Vec<Event> {
        EventGenerator::new(GeneratorConfig::default(), seed).take(n)
    }

    #[test]
    fn roundtrip_raw() {
        let evs = gen(100, 1);
        let brick =
            BrickFile::encode(BrickId::new(1, 0), &evs, Codec::Raw, 32);
        let (meta, decoded) = BrickFile::decode(&brick.bytes).unwrap();
        assert_eq!(meta.n_events, 100);
        assert_eq!(meta.n_pages, 4);
        assert_eq!(decoded, evs);
    }

    #[test]
    fn roundtrip_lzss() {
        let evs = gen(200, 2);
        let brick =
            BrickFile::encode(BrickId::new(2, 7), &evs, Codec::Lzss, 50);
        let (meta, decoded) = BrickFile::decode(&brick.bytes).unwrap();
        assert_eq!(meta.id, BrickId::new(2, 7));
        assert_eq!(decoded, evs);
    }

    #[test]
    fn empty_brick() {
        let brick = BrickFile::encode(BrickId::new(0, 0), &[], Codec::Raw, 16);
        let (meta, decoded) = BrickFile::decode(&brick.bytes).unwrap();
        assert_eq!(meta.n_events, 0);
        assert!(decoded.is_empty());
    }

    #[test]
    fn meta_only_decode() {
        let evs = gen(10, 3);
        let brick =
            BrickFile::encode(BrickId::new(5, 9), &evs, Codec::Lzss, 4);
        let meta = BrickFile::decode_meta(&brick.bytes).unwrap();
        assert_eq!(meta.id, BrickId::new(5, 9));
        assert_eq!(meta.n_events, 10);
        assert_eq!(meta.n_pages, 3);
    }

    #[test]
    fn bad_magic_rejected() {
        let evs = gen(5, 4);
        let mut brick =
            BrickFile::encode(BrickId::new(1, 1), &evs, Codec::Raw, 8);
        brick.bytes[0] = b'X';
        assert_eq!(
            BrickFile::decode(&brick.bytes).unwrap_err(),
            // trailer covers header too, so whole-file checksum trips first
            BrickError::ChecksumMismatch { page: None }
        );
        assert_eq!(
            BrickFile::decode_meta(&brick.bytes).unwrap_err(),
            BrickError::BadMagic
        );
    }

    #[test]
    fn payload_corruption_detected() {
        let evs = gen(50, 5);
        let mut brick =
            BrickFile::encode(BrickId::new(1, 2), &evs, Codec::Raw, 16);
        let mid = brick.bytes.len() / 2;
        brick.bytes[mid] ^= 0xff;
        assert!(matches!(
            BrickFile::decode(&brick.bytes).unwrap_err(),
            BrickError::ChecksumMismatch { .. }
        ));
    }

    #[test]
    fn truncation_detected() {
        let evs = gen(20, 6);
        let brick =
            BrickFile::encode(BrickId::new(1, 3), &evs, Codec::Raw, 8);
        for cut in [3usize, 20, brick.bytes.len() - 1] {
            assert!(BrickFile::decode(&brick.bytes[..cut]).is_err());
        }
    }

    #[test]
    fn compression_helps_on_real_events() {
        let evs = gen(500, 7);
        let raw = BrickFile::encode(BrickId::new(1, 4), &evs, Codec::Raw, 64);
        let lz = BrickFile::encode(BrickId::new(1, 4), &evs, Codec::Lzss, 64);
        assert!(lz.size() <= raw.size());
    }

    #[test]
    fn signal_flag_roundtrips() {
        let cfg = GeneratorConfig { signal_fraction: 0.5, ..Default::default() };
        let evs = EventGenerator::new(cfg, 8).take(64);
        let brick =
            BrickFile::encode(BrickId::new(3, 0), &evs, Codec::Lzss, 16);
        let (_, decoded) = BrickFile::decode(&brick.bytes).unwrap();
        for (a, b) in evs.iter().zip(&decoded) {
            assert_eq!(a.is_signal, b.is_signal);
        }
    }
}
