//! Byte codecs for the brick format: LEB128 varints and an LZSS-style
//! compressor with hash-chain match finding. Event payloads are float-heavy
//! but pattern-rich (repeated vertex indices, zero padding, similar
//! exponents), so a byte-oriented LZ gets a useful ratio without external
//! deps.
//!
//! Wire format of the compressed stream: a sequence of ops.
//!   literal run : 0x00, varint len, bytes
//!   match       : 0x01, varint len (>= MIN_MATCH), varint distance (>= 1)

/// Append a u64 as LEB128.
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Read a LEB128 u64; returns (value, bytes_consumed).
pub fn get_varint(data: &[u8]) -> Option<(u64, usize)> {
    let mut v = 0u64;
    let mut shift = 0;
    for (i, &b) in data.iter().enumerate() {
        if shift >= 64 {
            return None;
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Some((v, i + 1));
        }
        shift += 7;
    }
    None
}

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = 255;
const WINDOW: usize = 1 << 16;
const HASH_BITS: usize = 15;

#[inline]
fn hash4(b: &[u8]) -> usize {
    let v = u32::from_le_bytes(b[..4].try_into().unwrap());
    ((v.wrapping_mul(2654435761)) >> (32 - HASH_BITS)) as usize
}

/// LZSS compress. Worst case output is input + ~input/128 overhead.
pub fn compress(input: &[u8]) -> Vec<u8> {
    let n = input.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    if n == 0 {
        return out;
    }
    // hash table of last position for each 4-byte prefix hash
    let mut head = vec![usize::MAX; 1 << HASH_BITS];
    let mut i = 0;
    let mut lit_start = 0;

    let flush_literals = |out: &mut Vec<u8>, from: usize, to: usize,
                          input: &[u8]| {
        let mut s = from;
        while s < to {
            let len = (to - s).min(4096);
            out.push(0x00);
            put_varint(out, len as u64);
            out.extend_from_slice(&input[s..s + len]);
            s += len;
        }
    };

    // LZ4-style acceleration: every 32 consecutive match misses, grow the
    // stride through incompressible regions — cuts hash work ~8x on random
    // payloads (float-heavy event data) at negligible ratio cost.
    let mut misses = 0usize;
    while i + MIN_MATCH <= n {
        let h = hash4(&input[i..]);
        let cand = head[h];
        head[h] = i;

        let mut match_len = 0;
        if cand != usize::MAX && i - cand <= WINDOW {
            // cheap 4-byte prefilter before the byte loop
            if input[cand..cand + 4] == input[i..i + 4] {
                let max_len = (n - i).min(MAX_MATCH);
                let mut l = 4;
                while l < max_len && input[cand + l] == input[i + l] {
                    l += 1;
                }
                match_len = l;
            }
        }

        if match_len >= MIN_MATCH {
            misses = 0;
            flush_literals(&mut out, lit_start, i, input);
            out.push(0x01);
            put_varint(&mut out, match_len as u64);
            put_varint(&mut out, (i - cand) as u64);
            // index a few positions inside the match to keep the chain warm
            let end = i + match_len;
            let step = (match_len / 4).max(1);
            let mut j = i + 1;
            while j + MIN_MATCH <= end.min(n.saturating_sub(MIN_MATCH) + 1) {
                head[hash4(&input[j..])] = j;
                j += step;
            }
            i = end;
            lit_start = i;
        } else {
            misses += 1;
            i += 1 + (misses >> 4);
        }
    }
    flush_literals(&mut out, lit_start, n, input);
    out
}

/// Decompress; `expected_len` bounds allocation and validates the stream.
pub fn decompress(data: &[u8], expected_len: usize) -> Option<Vec<u8>> {
    let mut out = Vec::with_capacity(expected_len);
    let mut i = 0;
    while i < data.len() {
        let op = data[i];
        i += 1;
        match op {
            0x00 => {
                let (len, used) = get_varint(&data[i..])?;
                i += used;
                let len = len as usize;
                if i + len > data.len() || out.len() + len > expected_len {
                    return None;
                }
                out.extend_from_slice(&data[i..i + len]);
                i += len;
            }
            0x01 => {
                let (len, used) = get_varint(&data[i..])?;
                i += used;
                let (dist, used) = get_varint(&data[i..])?;
                i += used;
                let (len, dist) = (len as usize, dist as usize);
                if dist == 0 || dist > out.len()
                    || out.len() + len > expected_len
                {
                    return None;
                }
                let start = out.len() - dist;
                // may self-overlap: copy byte-by-byte
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
            _ => return None,
        }
    }
    if out.len() == expected_len {
        Some(out)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX];
        for &v in &vals {
            buf.clear();
            put_varint(&mut buf, v);
            let (got, used) = get_varint(&buf).unwrap();
            assert_eq!(got, v);
            assert_eq!(used, buf.len());
        }
    }

    #[test]
    fn varint_truncated_fails() {
        let mut buf = Vec::new();
        put_varint(&mut buf, 1 << 30);
        assert!(get_varint(&buf[..buf.len() - 1]).is_none());
        assert!(get_varint(&[]).is_none());
    }

    fn roundtrip(data: &[u8]) {
        let c = compress(data);
        let d = decompress(&c, data.len()).expect("decompress");
        assert_eq!(d, data);
    }

    #[test]
    fn empty_and_tiny() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"abc");
    }

    #[test]
    fn repetitive_compresses_well() {
        let data: Vec<u8> =
            b"eventeventevent".iter().cycle().take(10_000).copied().collect();
        let c = compress(&data);
        assert!(c.len() < data.len() / 4, "{} vs {}", c.len(), data.len());
        assert_eq!(decompress(&c, data.len()).unwrap(), data);
    }

    #[test]
    fn zeros_compress_extremely() {
        let data = vec![0u8; 65536];
        let c = compress(&data);
        assert!(c.len() < 2048);
        assert_eq!(decompress(&c, data.len()).unwrap(), data);
    }

    #[test]
    fn random_data_roundtrips() {
        let mut rng = Rng::new(77);
        for len in [1usize, 13, 256, 4096, 70000] {
            let data: Vec<u8> =
                (0..len).map(|_| rng.next_u64() as u8).collect();
            roundtrip(&data);
        }
    }

    #[test]
    fn float_like_payload_roundtrips() {
        let mut rng = Rng::new(3);
        let mut data = Vec::new();
        for _ in 0..5000 {
            data.extend_from_slice(
                &(rng.f32() * 100.0).to_le_bytes(),
            );
        }
        roundtrip(&data);
    }

    #[test]
    fn corrupt_stream_rejected() {
        let data: Vec<u8> =
            b"abcdabcdabcdabcd".iter().cycle().take(1000).copied().collect();
        let mut c = compress(&data);
        // bogus op code
        c[0] = 0x7f;
        assert!(decompress(&c, data.len()).is_none());
        // wrong expected length
        let c2 = compress(&data);
        assert!(decompress(&c2, data.len() + 1).is_none());
    }

    #[test]
    fn overlapping_match_decodes() {
        // 'aaaa...' forces distance-1 overlapping copies
        let data = vec![b'a'; 500];
        roundtrip(&data);
    }

    #[test]
    fn all_zero_every_length() {
        for len in [0usize, 1, 3, 4, 5, 127, 128, 4095, 4096, 4097, 70_000] {
            roundtrip(&vec![0u8; len]);
        }
    }

    #[test]
    fn all_distinct_bytes() {
        // no 4-byte repeats at all: pure literal path + the miss-stride
        // acceleration
        let data: Vec<u8> = (0..=255u8).collect();
        roundtrip(&data);
        // longer pseudo-distinct stream (wide-period LCG keeps 4-grams
        // effectively unique)
        let mut x = 1u32;
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                x = x.wrapping_mul(1664525).wrapping_add(1013904223);
                (x >> 24) as u8
            })
            .collect();
        roundtrip(&data);
    }

    #[test]
    fn repeated_four_byte_periods() {
        // exactly MIN_MATCH-periodic input: every position matches at
        // distance 4, the minimum representable useful match
        for period in [b"abcd".to_vec(), vec![0, 1, 2, 3], vec![255, 0, 255, 1]] {
            for len in [4usize, 7, 8, 16, 4096, 65_537] {
                let data: Vec<u8> =
                    period.iter().cycle().take(len).copied().collect();
                roundtrip(&data);
            }
        }
    }

    #[test]
    fn near_window_distances() {
        // a motif, WINDOW-ish bytes of incompressible filler, then the
        // motif again: matches right at / across the window boundary
        let motif: Vec<u8> = b"GEPSBRICKMOTIF00".to_vec();
        let mut rng = Rng::new(41);
        for gap in [
            WINDOW - MIN_MATCH - 1,
            WINDOW - motif.len() - 1,
            WINDOW - motif.len(),
            WINDOW - motif.len() + 1,
            WINDOW - 1,
            WINDOW,
            WINDOW + 1,
        ] {
            let mut data = motif.clone();
            data.extend((0..gap).map(|_| rng.next_u64() as u8));
            data.extend_from_slice(&motif);
            roundtrip(&data);
        }
    }

    #[test]
    fn varint_ten_bytes_is_max() {
        // u64::MAX encodes to exactly 10 bytes and roundtrips
        let mut buf = Vec::new();
        put_varint(&mut buf, u64::MAX);
        assert_eq!(buf.len(), 10);
        assert_eq!(get_varint(&buf), Some((u64::MAX, 10)));
    }

    #[test]
    fn varint_overlong_rejected() {
        // an 11th continuation byte shifts past 64 bits: must be None,
        // not a wrap or a panic
        let mut buf = vec![0x80u8; 10];
        buf.push(0x01);
        assert_eq!(get_varint(&buf), None);
        // ... and a run of continuation bytes with no terminator
        assert_eq!(get_varint(&[0x80; 12]), None);
        assert_eq!(get_varint(&[0x80]), None);
    }

    #[test]
    fn decompress_match_before_start_rejected() {
        // hand-built stream: a match whose distance exceeds the bytes
        // produced so far must be rejected
        let mut c = Vec::new();
        c.push(0x00); // literal run
        put_varint(&mut c, 2);
        c.extend_from_slice(b"ab");
        c.push(0x01); // match len 4 dist 5 — only 2 bytes exist
        put_varint(&mut c, 4);
        put_varint(&mut c, 5);
        assert_eq!(decompress(&c, 6), None);
    }

    #[test]
    fn decompress_truncated_varint_rejected() {
        let data: Vec<u8> = b"abcdabcdabcd".to_vec();
        let c = compress(&data);
        // chop the stream mid-token at every length: never a panic,
        // never a wrong answer
        for cut in 0..c.len() {
            match decompress(&c[..cut], data.len()) {
                None => {}
                Some(d) => assert_eq!(d, data),
            }
        }
    }
}
