//! Replica sets: tracking which nodes hold a live copy of each brick and
//! choosing a read target, with failover. This is the paper's §7
//! "redundancy mechanism to recover from a malfunction in the nodes",
//! built as a first-class feature.

use crate::brick::BrickId;
use std::collections::{BTreeMap, BTreeSet};

/// Live view of a brick's replicas.
#[derive(Debug, Clone, Default)]
pub struct ReplicaSet {
    /// holders in placement order (primary first)
    holders: Vec<String>,
}

impl ReplicaSet {
    pub fn new(holders: Vec<String>) -> Self {
        ReplicaSet { holders }
    }

    pub fn holders(&self) -> &[String] {
        &self.holders
    }

    /// First holder not in `down` — the node a job should read from.
    pub fn pick_live(&self, down: &BTreeSet<String>) -> Option<&str> {
        self.holders
            .iter()
            .find(|h| !down.contains(h.as_str()))
            .map(|s| s.as_str())
    }

    pub fn contains(&self, node: &str) -> bool {
        self.holders.iter().any(|h| h == node)
    }
}

/// Directory of all bricks' replicas — the metadata the catalogue serves
/// and the scheduler consults.
#[derive(Debug, Clone, Default)]
pub struct ReplicaDirectory {
    map: BTreeMap<BrickId, ReplicaSet>,
}

impl ReplicaDirectory {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, id: BrickId, holders: Vec<String>) {
        self.map.insert(id, ReplicaSet::new(holders));
    }

    pub fn get(&self, id: BrickId) -> Option<&ReplicaSet> {
        self.map.get(&id)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&BrickId, &ReplicaSet)> {
        self.map.iter()
    }

    /// Bricks whose ONLY live replica is on `node` — these become
    /// unreadable if `node` dies (the paper's "biggest disadvantage").
    pub fn sole_holder_bricks(
        &self,
        node: &str,
        down: &BTreeSet<String>,
    ) -> Vec<BrickId> {
        self.map
            .iter()
            .filter(|(_, rs)| {
                let live: Vec<&String> = rs
                    .holders
                    .iter()
                    .filter(|h| !down.contains(h.as_str()))
                    .collect();
                live.len() == 1 && live[0] == node
            })
            .map(|(id, _)| *id)
            .collect()
    }

    /// All bricks readable given `down` nodes; Err(list) if any brick has
    /// lost all replicas (job must fail loudly, not silently skip data).
    pub fn check_readable(
        &self,
        down: &BTreeSet<String>,
    ) -> Result<(), Vec<BrickId>> {
        let lost: Vec<BrickId> = self
            .map
            .iter()
            .filter(|(_, rs)| rs.pick_live(down).is_none())
            .map(|(id, _)| *id)
            .collect();
        if lost.is_empty() {
            Ok(())
        } else {
            Err(lost)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(h: &[&str]) -> ReplicaSet {
        ReplicaSet::new(h.iter().map(|s| s.to_string()).collect())
    }

    fn down(ns: &[&str]) -> BTreeSet<String> {
        ns.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn pick_live_prefers_primary() {
        let rs = set(&["a", "b", "c"]);
        assert_eq!(rs.pick_live(&down(&[])), Some("a"));
        assert_eq!(rs.pick_live(&down(&["a"])), Some("b"));
        assert_eq!(rs.pick_live(&down(&["a", "b"])), Some("c"));
        assert_eq!(rs.pick_live(&down(&["a", "b", "c"])), None);
    }

    #[test]
    fn sole_holder_detection() {
        let mut dir = ReplicaDirectory::new();
        dir.insert(BrickId::new(1, 0), vec!["a".into(), "b".into()]);
        dir.insert(BrickId::new(1, 1), vec!["a".into()]);
        dir.insert(BrickId::new(1, 2), vec!["b".into()]);
        let sole = dir.sole_holder_bricks("a", &down(&[]));
        assert_eq!(sole, vec![BrickId::new(1, 1)]);
        // with b down, brick 0 also becomes sole-held by a
        let sole = dir.sole_holder_bricks("a", &down(&["b"]));
        assert_eq!(sole, vec![BrickId::new(1, 0), BrickId::new(1, 1)]);
    }

    #[test]
    fn readable_check() {
        let mut dir = ReplicaDirectory::new();
        dir.insert(BrickId::new(1, 0), vec!["a".into(), "b".into()]);
        dir.insert(BrickId::new(1, 1), vec!["b".into()]);
        assert!(dir.check_readable(&down(&["a"])).is_ok());
        let lost = dir.check_readable(&down(&["b"])).unwrap_err();
        assert_eq!(lost, vec![BrickId::new(1, 1)]);
    }
}
