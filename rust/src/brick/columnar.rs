//! Column-wise (SoA) event storage — the in-memory twin of the v2 brick
//! page layout and the substrate of the per-node hot path.
//!
//! The paper's premise is that brick-holding nodes do the event
//! processing locally, so aggregate throughput is the sum of per-node
//! hot paths (§4.1). Row-wise `Event` structs fight that: every decoded
//! event costs two heap allocations (`Vec<Track>`, `Vec<Vertex>`) that
//! are immediately torn apart again when `EventBatch::pack` builds the
//! SoA tensors the kernel wants. [`ColumnarEvents`] keeps the data in
//! column form end to end: one flat buffer per field, with per-event
//! offset tables, so a brick decodes into kernel-ready columns with
//! **zero per-event allocation** and batches are packed by slicing.
//!
//! Invariants (checked by the brick decoder, assumed everywhere else):
//! - `ids`, `signal` have length `n` (the event count);
//! - `track_off` and `vert_off` have length `n + 1`, start at 0, and are
//!   non-decreasing; `track_off[n]` equals the track-column lengths;
//! - the five track columns (`e`, `px`, `py`, `pz`, `track_vertex`)
//!   share one length, as do the four vertex columns.

use crate::events::model::{Event, Track, Vertex};
use crate::events::EventBatch;

/// A set of events stored column-wise. Event `i` owns tracks
/// `track_off[i]..track_off[i+1]` and vertices `vert_off[i]..vert_off[i+1]`
/// of the flat columns.
#[derive(Debug, Clone, PartialEq)]
pub struct ColumnarEvents {
    /// Event ids (run << 32 | index), one per event.
    pub ids: Vec<u64>,
    /// Generator truth label (0/1), one per event — never kernel-visible.
    pub signal: Vec<u8>,
    /// Track offset table, `len() + 1` entries, `track_off[0] == 0`.
    pub track_off: Vec<u32>,
    /// Track energy column (GeV).
    pub e: Vec<f32>,
    /// Track momentum columns (GeV).
    pub px: Vec<f32>,
    pub py: Vec<f32>,
    pub pz: Vec<f32>,
    /// Per-track vertex association (index into the event's vertex list).
    pub track_vertex: Vec<u16>,
    /// Vertex offset table, `len() + 1` entries, `vert_off[0] == 0`.
    pub vert_off: Vec<u32>,
    /// Vertex position columns.
    pub vx: Vec<f32>,
    pub vy: Vec<f32>,
    pub vz: Vec<f32>,
    /// Per-vertex associated-track count.
    pub vert_ntracks: Vec<u16>,
}

impl Default for ColumnarEvents {
    fn default() -> Self {
        ColumnarEvents::new()
    }
}

impl ColumnarEvents {
    pub fn new() -> Self {
        ColumnarEvents {
            ids: Vec::new(),
            signal: Vec::new(),
            track_off: vec![0],
            e: Vec::new(),
            px: Vec::new(),
            py: Vec::new(),
            pz: Vec::new(),
            track_vertex: Vec::new(),
            vert_off: vec![0],
            vx: Vec::new(),
            vy: Vec::new(),
            vz: Vec::new(),
            vert_ntracks: Vec::new(),
        }
    }

    /// Pre-size the columns. Writers know all three totals up front;
    /// the brick decoder knows only `n_events` (track/vertex totals live
    /// inside each — possibly compressed — page payload), so it passes
    /// zeros and relies on the bulk column readers' per-page `reserve`
    /// for amortized growth.
    pub fn with_capacity(n_events: usize, n_tracks: usize, n_verts: usize) -> Self {
        let mut c = ColumnarEvents::new();
        c.ids.reserve(n_events);
        c.signal.reserve(n_events);
        c.track_off.reserve(n_events + 1);
        c.vert_off.reserve(n_events + 1);
        c.e.reserve(n_tracks);
        c.px.reserve(n_tracks);
        c.py.reserve(n_tracks);
        c.pz.reserve(n_tracks);
        c.track_vertex.reserve(n_tracks);
        c.vx.reserve(n_verts);
        c.vy.reserve(n_verts);
        c.vz.reserve(n_verts);
        c.vert_ntracks.reserve(n_verts);
        c
    }

    /// Number of events.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Total tracks across all events.
    pub fn n_tracks_total(&self) -> usize {
        self.e.len()
    }

    /// Total vertices across all events.
    pub fn n_verts_total(&self) -> usize {
        self.vx.len()
    }

    /// Track span of event `i` in the flat track columns.
    #[inline]
    pub fn tracks_range(&self, i: usize) -> std::ops::Range<usize> {
        self.track_off[i] as usize..self.track_off[i + 1] as usize
    }

    /// Vertex span of event `i` in the flat vertex columns.
    #[inline]
    pub fn verts_range(&self, i: usize) -> std::ops::Range<usize> {
        self.vert_off[i] as usize..self.vert_off[i + 1] as usize
    }

    /// Append one row-wise event (writer path and v1 migration).
    pub fn push_event(&mut self, ev: &Event) {
        self.ids.push(ev.id);
        self.signal.push(ev.is_signal as u8);
        for t in &ev.tracks {
            self.e.push(t.e);
            self.px.push(t.px);
            self.py.push(t.py);
            self.pz.push(t.pz);
            self.track_vertex.push(t.vertex);
        }
        self.track_off.push(self.e.len() as u32);
        for v in &ev.vertices {
            self.vx.push(v.x);
            self.vy.push(v.y);
            self.vz.push(v.z);
            self.vert_ntracks.push(v.n_tracks);
        }
        self.vert_off.push(self.vx.len() as u32);
    }

    /// Convert a row-wise slice (writer path).
    pub fn from_events(events: &[Event]) -> Self {
        let n_tracks: usize = events.iter().map(|e| e.tracks.len()).sum();
        let n_verts: usize = events.iter().map(|e| e.vertices.len()).sum();
        let mut c = ColumnarEvents::with_capacity(events.len(), n_tracks, n_verts);
        for ev in events {
            c.push_event(ev);
        }
        c
    }

    /// Materialize event `i` as a row-wise struct (migration / result
    /// inspection — NOT the hot path).
    pub fn event(&self, i: usize) -> Event {
        let tr = self.tracks_range(i);
        let vr = self.verts_range(i);
        Event {
            id: self.ids[i],
            tracks: tr
                .map(|t| Track {
                    e: self.e[t],
                    px: self.px[t],
                    py: self.py[t],
                    pz: self.pz[t],
                    vertex: self.track_vertex[t],
                })
                .collect(),
            vertices: vr
                .map(|v| Vertex {
                    x: self.vx[v],
                    y: self.vy[v],
                    z: self.vz[v],
                    n_tracks: self.vert_ntracks[v],
                })
                .collect(),
            is_signal: self.signal[i] != 0,
        }
    }

    /// Materialize events `a..b` row-wise (compatibility path).
    pub fn events_range(&self, a: usize, b: usize) -> Vec<Event> {
        (a..b).map(|i| self.event(i)).collect()
    }

    /// Materialize all events row-wise.
    pub fn to_events(&self) -> Vec<Event> {
        self.events_range(0, self.len())
    }

    /// Append all of `other`, rebasing its offset tables — a general
    /// column-set merge utility (the brick decoder appends pages
    /// directly into one shared buffer instead).
    pub fn append(&mut self, other: &ColumnarEvents) {
        let t0 = self.e.len() as u32;
        let v0 = self.vx.len() as u32;
        self.ids.extend_from_slice(&other.ids);
        self.signal.extend_from_slice(&other.signal);
        self.track_off
            .extend(other.track_off[1..].iter().map(|o| o + t0));
        self.vert_off
            .extend(other.vert_off[1..].iter().map(|o| o + v0));
        self.e.extend_from_slice(&other.e);
        self.px.extend_from_slice(&other.px);
        self.py.extend_from_slice(&other.py);
        self.pz.extend_from_slice(&other.pz);
        self.track_vertex.extend_from_slice(&other.track_vertex);
        self.vx.extend_from_slice(&other.vx);
        self.vy.extend_from_slice(&other.vy);
        self.vz.extend_from_slice(&other.vz);
        self.vert_ntracks.extend_from_slice(&other.vert_ntracks);
    }

    /// Gather the events at `idx` (ascending global indices) into a new
    /// column set — the result-brick path: selected events leave the node
    /// without ever becoming row-wise structs.
    pub fn select(&self, idx: &[u32]) -> ColumnarEvents {
        let n_tracks: usize = idx
            .iter()
            .map(|&i| self.tracks_range(i as usize).len())
            .sum();
        let n_verts: usize = idx
            .iter()
            .map(|&i| self.verts_range(i as usize).len())
            .sum();
        let mut out = ColumnarEvents::with_capacity(idx.len(), n_tracks, n_verts);
        for &i in idx {
            let i = i as usize;
            out.ids.push(self.ids[i]);
            out.signal.push(self.signal[i]);
            let tr = self.tracks_range(i);
            out.e.extend_from_slice(&self.e[tr.clone()]);
            out.px.extend_from_slice(&self.px[tr.clone()]);
            out.py.extend_from_slice(&self.py[tr.clone()]);
            out.pz.extend_from_slice(&self.pz[tr.clone()]);
            out.track_vertex.extend_from_slice(&self.track_vertex[tr]);
            out.track_off.push(out.e.len() as u32);
            let vr = self.verts_range(i);
            out.vx.extend_from_slice(&self.vx[vr.clone()]);
            out.vy.extend_from_slice(&self.vy[vr.clone()]);
            out.vz.extend_from_slice(&self.vz[vr.clone()]);
            out.vert_ntracks
                .extend_from_slice(&self.vert_ntracks[vr]);
            out.vert_off.push(out.vx.len() as u32);
        }
        out
    }

    /// Pack events `range.0..range.1` into a kernel-ready batch —
    /// byte-identical to `EventBatch::pack` over the same row-wise
    /// events, with no intermediate `Event` structs. Events beyond
    /// `batch` rows are ignored; tracks beyond `max_tracks` are dropped
    /// (same truncation rule as `pack`).
    pub fn pack_range(
        &self,
        range: (usize, usize),
        batch: usize,
        max_tracks: usize,
    ) -> EventBatch {
        let (a, b) = range;
        debug_assert!(a <= b && b <= self.len());
        let mut out = EventBatch::zeroed(batch, max_tracks);
        for (row, i) in (a..b.min(a + batch)).enumerate() {
            let tr = self.tracks_range(i);
            out.fill_event(
                row,
                self.ids[i],
                &self.e[tr.clone()],
                &self.px[tr.clone()],
                &self.py[tr.clone()],
                &self.pz[tr],
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{EventGenerator, GeneratorConfig};

    fn gen(n: usize, seed: u64) -> Vec<Event> {
        EventGenerator::new(GeneratorConfig::default(), seed).take(n)
    }

    #[test]
    fn roundtrip_through_columns() {
        let evs = gen(120, 1);
        let cols = ColumnarEvents::from_events(&evs);
        assert_eq!(cols.len(), 120);
        assert_eq!(cols.to_events(), evs);
        // single-event materialization agrees
        assert_eq!(cols.event(7), evs[7]);
    }

    #[test]
    fn offsets_are_consistent() {
        let evs = gen(50, 2);
        let cols = ColumnarEvents::from_events(&evs);
        assert_eq!(cols.track_off.len(), 51);
        assert_eq!(cols.vert_off.len(), 51);
        assert_eq!(cols.track_off[0], 0);
        assert_eq!(
            *cols.track_off.last().unwrap() as usize,
            cols.n_tracks_total()
        );
        assert_eq!(
            *cols.vert_off.last().unwrap() as usize,
            cols.n_verts_total()
        );
        for i in 0..50 {
            assert!(cols.track_off[i] <= cols.track_off[i + 1]);
            assert_eq!(cols.tracks_range(i).len(), evs[i].tracks.len());
            assert_eq!(cols.verts_range(i).len(), evs[i].vertices.len());
        }
    }

    #[test]
    fn pack_range_matches_rowwise_pack() {
        let evs = gen(100, 3);
        let cols = ColumnarEvents::from_events(&evs);
        for (a, b, batch, max_tracks) in
            [(0, 100, 128, 32), (10, 42, 32, 32), (90, 100, 32, 4), (5, 5, 8, 8)]
        {
            let row = EventBatch::pack(&evs[a..b], batch, max_tracks);
            let col = cols.pack_range((a, b), batch, max_tracks);
            assert_eq!(col, row, "range {a}..{b} batch {batch}x{max_tracks}");
        }
    }

    #[test]
    fn pack_range_caps_at_batch_rows() {
        let evs = gen(40, 4);
        let cols = ColumnarEvents::from_events(&evs);
        let row = EventBatch::pack(&evs[0..40], 16, 32);
        let col = cols.pack_range((0, 40), 16, 32);
        assert_eq!(col, row);
        assert_eq!(col.n_real(), 16);
    }

    #[test]
    fn append_concatenates() {
        let evs = gen(60, 5);
        let a = ColumnarEvents::from_events(&evs[..25]);
        let b = ColumnarEvents::from_events(&evs[25..]);
        let mut joined = a;
        joined.append(&b);
        assert_eq!(joined, ColumnarEvents::from_events(&evs));
    }

    #[test]
    fn select_gathers_rows() {
        let evs = gen(30, 6);
        let cols = ColumnarEvents::from_events(&evs);
        let idx = [0u32, 3, 7, 29];
        let sel = cols.select(&idx);
        let expect: Vec<Event> =
            idx.iter().map(|&i| evs[i as usize].clone()).collect();
        assert_eq!(sel.to_events(), expect);
    }

    #[test]
    fn empty_set() {
        let cols = ColumnarEvents::new();
        assert!(cols.is_empty());
        assert!(cols.to_events().is_empty());
        let sel = cols.select(&[]);
        assert_eq!(sel, cols);
        let b = cols.pack_range((0, 0), 4, 4);
        assert_eq!(b.n_real(), 0);
    }
}
