//! The **grid-brick** data layer: the paper's core idea is that event data
//! is pre-split into bricks that live on the grid nodes' own disks, so
//! jobs move to the data instead of the reverse (§4: "data should not be
//! moved when applying for a job submission").
//!
//! - [`codec`]: LZSS compression + varints (substrate — we build our own)
//! - [`format`]: the on-disk/on-wire brick file format (the ROOT-tree
//!   analogue: paged, checksummed, optionally compressed; v1 row-wise
//!   pages for migration, v2 columnar pages for the hot path)
//! - [`columnar`]: column-wise (SoA) event storage — what v2 pages
//!   decode into, and what the node packs kernel batches from with zero
//!   per-event allocation
//! - [`split`]: splitting an event stream into bricks + placement
//! - [`replica`]: replication sets (paper §7 future work, built here)

pub mod codec;
pub mod columnar;
pub mod format;
pub mod replica;
pub mod split;

pub use columnar::ColumnarEvents;
pub use format::{BrickFile, BrickMeta, Codec};
pub use replica::ReplicaSet;
pub use split::{placement_nodes, split_events, BrickPlacement, SplitConfig};

/// Identifier of a brick: (dataset, sequence).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BrickId {
    pub dataset: u32,
    pub seq: u32,
}

impl BrickId {
    pub fn new(dataset: u32, seq: u32) -> Self {
        BrickId { dataset, seq }
    }
}

impl std::fmt::Display for BrickId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "d{}.b{}", self.dataset, self.seq)
    }
}
