//! Metrics: counters, gauges and streaming histograms for the
//! coordinator, plus a run-report formatter. Lock-free on the hot path
//! (atomics); histograms use fixed log2 buckets so recording is O(1) with
//! no allocation.

use crate::util::lock;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// The single declared registry of every metric name this crate emits.
///
/// `gepslint`'s `metric-name-registry` pass cross-checks each
/// `.counter()/.gauge()/.histogram()` call site against this list (and
/// flags registered names that are never emitted), so dashboards and
/// scrapers can treat it as the complete, authoritative metric
/// catalogue. Formatted families use a `*` wildcard segment.
pub mod names {
    pub const REGISTERED: &[&str] = &[
        "cluster.nodes_joined",
        "cluster.nodes_killed",
        "faultline.injected.*",
        "ft.bricks_rebalanced",
        "ft.bricks_rereplicated",
        "ft.bricks_unrecoverable",
        "ft.nodes_quarantined",
        "gass.transfer_retries",
        "jse.job_wall_ns",
        "jse.jobs_cancelled",
        "jse.jobs_discovered",
        "jse.jobs_done",
        "jse.jobs_failed",
        "jse.jobs_failed_explicitly",
        "jse.jobs_in_flight",
        "jse.jobs_policy.*",
        "jse.jobs_queued",
        "jse.nodes_joined",
        "jse.nodes_lost",
        "jse.speculation_wins",
        "jse.stale_messages",
        "jse.task_busy_ns",
        "jse.task_deadline_ns",
        "jse.tasks_dispatched",
        "jse.tasks_failed_over",
        "jse.tasks_outstanding",
        "jse.tasks_speculated",
        "node.drain_reorder_depth",
        "node.pack_stall_ns",
        "node.pipeline.*.task_busy_ns",
        "node.pipelines",
        "node.tasks_done",
        "node.tasks_failed",
        "node.tasks_in_flight",
        "obs.trace_dropped",
        "obs.trace_events",
        "portal.cancels",
        "portal.submissions",
        "portal.submissions_rejected",
        "qcache.bytes",
        "qcache.evictions",
        "qcache.hits_full",
        "qcache.hits_partial",
        "qcache.promotions",
        "qcache.shared_jobs",
        "qcache.uncacheable_results",
        "runtime.backend_selfcheck_ulps",
    ];
}

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A settable point-in-time value (queue depths, in-flight counts).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Atomic increment — safe under concurrent writers, unlike the
    /// read-modify-write `set(get() + n)` pattern which loses updates.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Atomic saturating decrement (a gauge at 0 stays at 0 rather
    /// than wrapping — an unmatched `sub` must not explode the value).
    pub fn sub(&self, n: u64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// Log2-bucketed latency/size histogram (ns or bytes). 64 buckets cover
/// the full u64 range.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; 64],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        let b = 63 - v.max(1).leading_zeros() as usize;
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th sample). Bucket `i` holds values in
    /// `[2^i, 2^(i+1))`, so its upper bound is `2^(i+1) - 1`; the top
    /// bucket (63) is unbounded above and reports `u64::MAX`.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        // Floor the rank at 1: at q=0 `ceil` yields target 0, which made
        // `seen >= target` vacuously true at bucket 0 even when bucket 0
        // was empty. q=0 means "the smallest recorded sample", i.e. the
        // upper bound of the first *non-empty* bucket.
        let target = (((q.clamp(0.0, 1.0)) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_upper_bound(i);
            }
        }
        u64::MAX
    }

    /// Inclusive upper bound of bucket `i` (`2^(i+1) - 1`; `u64::MAX`
    /// for the open-ended top bucket).
    pub fn bucket_upper_bound(i: usize) -> u64 {
        if i >= 63 {
            u64::MAX
        } else {
            (1u64 << (i + 1)) - 1
        }
    }

    /// Point-in-time copy of the per-bucket counts (for exposition
    /// renderers — the raw buckets stay private).
    pub fn bucket_counts(&self) -> [u64; 64] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Fold another histogram's snapshot into this one, element-wise.
    /// Bucket adds commute, so merging per-node partials in sorted node
    /// order reproduces the exact counts a single shared histogram
    /// would have accumulated (the federation bit-identity contract).
    pub fn merge_from(&self, buckets: &[u64; 64], sum: u64, count: u64) {
        for (i, b) in buckets.iter().enumerate() {
            if *b > 0 {
                self.buckets[i].fetch_add(*b, Ordering::Relaxed);
            }
        }
        self.sum.fetch_add(sum, Ordering::Relaxed);
        self.count.fetch_add(count, Ordering::Relaxed);
    }
}

/// Point-in-time copy of one histogram (buckets, sum, count).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; 64],
    pub sum: u64,
    pub count: u64,
}

impl HistSnapshot {
    /// Same bucket-walk quantile as [`Histogram::quantile`], over the
    /// frozen copy.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (((q.clamp(0.0, 1.0)) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return Histogram::bucket_upper_bound(i);
            }
        }
        u64::MAX
    }
}

/// A deterministic, serialisable snapshot of a whole [`Registry`] —
/// the unit a node ships to the JSE in a `MetricsReport`. Cumulative
/// (not a delta): reports are idempotent, so a dropped or reordered
/// report never skews the fold — the freshest sequence number wins.
///
/// All maps are BTreeMaps and the wire encoding walks them in key
/// order, so the same registry state always encodes to the same bytes.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl Snapshot {
    /// Capture the registry's current state.
    pub fn from_registry(r: &Registry) -> Self {
        let mut s = Snapshot::default();
        for (n, v) in r.counters_snapshot() {
            s.counters.insert(n, v);
        }
        for (n, v) in r.gauges_snapshot() {
            s.gauges.insert(n, v);
        }
        for (n, buckets, sum, count) in r.histograms_snapshot() {
            s.hists.insert(n, HistSnapshot { buckets, sum, count });
        }
        s
    }

    /// Canonical byte encoding: three sections (counters, gauges,
    /// histograms), each a varint entry count followed by sorted
    /// entries. Histogram buckets are sparse `(index, count)` pairs.
    pub fn encode(&self) -> Vec<u8> {
        use crate::brick::codec::put_varint;
        let mut out = Vec::new();
        let put_str = |out: &mut Vec<u8>, s: &str| {
            put_varint(out, s.len() as u64);
            out.extend_from_slice(s.as_bytes());
        };
        for section in [&self.counters, &self.gauges] {
            put_varint(&mut out, section.len() as u64);
            for (n, v) in section.iter() {
                put_str(&mut out, n);
                put_varint(&mut out, *v);
            }
        }
        put_varint(&mut out, self.hists.len() as u64);
        for (n, h) in self.hists.iter() {
            put_str(&mut out, n);
            let nonzero: Vec<(usize, u64)> = h
                .buckets
                .iter()
                .enumerate()
                .filter(|(_, c)| **c > 0)
                .map(|(i, c)| (i, *c))
                .collect();
            put_varint(&mut out, nonzero.len() as u64);
            for (i, c) in nonzero {
                put_varint(&mut out, i as u64);
                put_varint(&mut out, c);
            }
            put_varint(&mut out, h.sum);
            put_varint(&mut out, h.count);
        }
        out
    }

    /// Decode an [`encode`](Self::encode)d snapshot. `None` on any
    /// malformed input (truncation, bucket index out of range,
    /// invalid UTF-8, trailing bytes).
    pub fn decode(data: &[u8]) -> Option<Self> {
        use crate::brick::codec::get_varint;
        let mut i = 0usize;
        let mut next = |data: &[u8], i: &mut usize| -> Option<u64> {
            let (v, n) = get_varint(data.get(*i..)?)?;
            *i += n;
            Some(v)
        };
        let mut read_str = |data: &[u8], i: &mut usize| -> Option<String> {
            let (len, n) = get_varint(data.get(*i..)?)?;
            *i += n;
            let end = i.checked_add(len as usize)?;
            let s = std::str::from_utf8(data.get(*i..end)?).ok()?.to_string();
            *i = end;
            Some(s)
        };
        let mut s = Snapshot::default();
        for section in [&mut s.counters, &mut s.gauges] {
            let n = next(data, &mut i)?;
            for _ in 0..n {
                let name = read_str(data, &mut i)?;
                let v = next(data, &mut i)?;
                section.insert(name, v);
            }
        }
        let nh = next(data, &mut i)?;
        for _ in 0..nh {
            let name = read_str(data, &mut i)?;
            let mut h = HistSnapshot { buckets: [0u64; 64], sum: 0, count: 0 };
            let nb = next(data, &mut i)?;
            for _ in 0..nb {
                let idx = next(data, &mut i)?;
                let c = next(data, &mut i)?;
                *h.buckets.get_mut(idx as usize)? += c;
            }
            h.sum = next(data, &mut i)?;
            h.count = next(data, &mut i)?;
            s.hists.insert(name, h);
        }
        if i != data.len() {
            return None; // trailing garbage
        }
        Some(s)
    }

    /// Fold this snapshot into a registry: counters and histograms
    /// add, gauges take the max (every node publishes the same value
    /// for shared-shape gauges like `node.pipelines`, and max keeps
    /// point-in-time gauges from summing across nodes).
    pub fn merge_into(&self, r: &Registry) {
        for (n, v) in self.counters.iter() {
            r.counter(n).add(*v);
        }
        for (n, v) in self.gauges.iter() {
            let g = r.gauge(n);
            if *v > g.get() {
                g.set(*v);
            }
        }
        for (n, h) in self.hists.iter() {
            r.histogram(n).merge_from(&h.buckets, h.sum, h.count);
        }
    }
}

/// Named metric registry.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        lock(&self.counters).entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        lock(&self.gauges).entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        lock(&self.histograms)
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Histogram::new()))
            .clone()
    }

    /// Text dump (portal /metrics endpoint). Deterministic: the maps
    /// are BTreeMaps, so names render in sorted order regardless of
    /// registration order (snapshot ordering is part of the repo's
    /// bit-identity surface — scrapers diff these dumps).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in lock(&self.counters).iter() {
            out.push_str(&format!("counter {name} {}\n", c.get()));
        }
        for (name, g) in lock(&self.gauges).iter() {
            out.push_str(&format!("gauge {name} {}\n", g.get()));
        }
        for (name, h) in lock(&self.histograms).iter() {
            out.push_str(&format!(
                "hist {name} count={} mean={:.1} p50<={} p99<={}\n",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99),
            ));
        }
        out
    }

    /// Sorted point-in-time counter snapshot (name, value).
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        lock(&self.counters)
            .iter()
            .map(|(n, c)| (n.clone(), c.get()))
            .collect()
    }

    /// Sorted point-in-time gauge snapshot (name, value).
    pub fn gauges_snapshot(&self) -> Vec<(String, u64)> {
        lock(&self.gauges)
            .iter()
            .map(|(n, g)| (n.clone(), g.get()))
            .collect()
    }

    /// Sorted point-in-time histogram snapshot
    /// (name, per-bucket counts, sum, count).
    #[allow(clippy::type_complexity)]
    pub fn histograms_snapshot(&self) -> Vec<(String, [u64; 64], u64, u64)> {
        lock(&self.histograms)
            .iter()
            .map(|(n, h)| (n.clone(), h.bucket_counts(), h.sum(), h.count()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::new();
        for v in [1u64, 2, 4, 8, 1024, 1_000_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert!(h.mean() > 0.0);
        assert!(h.quantile(0.5) <= 1024);
        assert!(h.quantile(1.0) >= 1_000_000);
        let empty = Histogram::new();
        assert_eq!(empty.quantile(0.5), 0);
    }

    #[test]
    fn quantile_upper_bounds_are_exact() {
        // regression: the old `(i + 1).min(63)` cap made buckets 62 and
        // 63 both report `1 << 63`, understating large-sample p99. The
        // top bucket must saturate to u64::MAX, and every lower bucket
        // must report `2^(i+1) - 1` (the largest value it can hold).
        let h = Histogram::new();
        h.record(u64::MAX); // bucket 63
        assert_eq!(h.quantile(1.0), u64::MAX);
        let h62 = Histogram::new();
        h62.record(1u64 << 62); // bucket 62
        assert_eq!(h62.quantile(1.0), (1u64 << 63) - 1);
        let small = Histogram::new();
        small.record(3); // bucket 1: [2, 4)
        assert_eq!(small.quantile(0.5), 3);
        assert_eq!(Histogram::bucket_upper_bound(0), 1);
        assert_eq!(Histogram::bucket_upper_bound(63), u64::MAX);
    }

    #[test]
    fn quantile_zero_skips_empty_low_buckets() {
        // regression (alongside the 62/63 upper-bound fix): q=0 used to
        // compute target 0, making `seen >= target` vacuously true at
        // bucket 0 — an empty bucket 0 still reported upper bound 1.
        // q=0 must return the first *non-empty* bucket's upper bound.
        let h = Histogram::new();
        h.record(1024); // bucket 10: [1024, 2048)
        assert_eq!(h.quantile(0.0), 2047);
        let low = Histogram::new();
        low.record(1); // bucket 0
        assert_eq!(low.quantile(0.0), 1);
    }

    #[test]
    fn gauge_add_sub_are_atomic_and_saturating() {
        let g = std::sync::Arc::new(Gauge::default());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    g.add(1);
                    g.sub(1);
                }
                g.add(2);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // the read-modify-write set(get()±1) pattern would lose updates
        // here; the atomic helpers must land every one of them
        assert_eq!(g.get(), 16);
        g.sub(100);
        assert_eq!(g.get(), 0, "sub must saturate at zero, not wrap");
    }

    #[test]
    fn histogram_merge_from_is_elementwise() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in [1u64, 3, 1024] {
            a.record(v);
        }
        for v in [3u64, 5, 1 << 40] {
            b.record(v);
        }
        let merged = Histogram::new();
        merged.merge_from(&a.bucket_counts(), a.sum(), a.count());
        merged.merge_from(&b.bucket_counts(), b.sum(), b.count());
        let oracle = Histogram::new();
        for v in [1u64, 3, 1024, 3, 5, 1 << 40] {
            oracle.record(v);
        }
        assert_eq!(merged.bucket_counts(), oracle.bucket_counts());
        assert_eq!(merged.sum(), oracle.sum());
        assert_eq!(merged.count(), oracle.count());
    }

    #[test]
    fn snapshot_roundtrip_and_determinism() {
        let r = Registry::new();
        r.counter("node.tasks_done").add(7);
        r.gauge("node.tasks_in_flight").set(3);
        r.histogram("node.pack_stall_ns").record(4096);
        r.histogram("node.pack_stall_ns").record(12);
        let s = Snapshot::from_registry(&r);
        let bytes = s.encode();
        assert_eq!(bytes, s.encode(), "encode must be deterministic");
        let back = Snapshot::decode(&bytes).expect("roundtrip");
        assert_eq!(back, s);
        assert_eq!(back.counters["node.tasks_done"], 7);
        assert_eq!(back.hists["node.pack_stall_ns"].count, 2);
        // malformed inputs are rejected, not panicked on
        assert!(Snapshot::decode(&bytes[..bytes.len() - 1]).is_none());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(Snapshot::decode(&trailing).is_none());
        assert!(Snapshot::decode(&[0xff]).is_none());
    }

    #[test]
    fn snapshot_merge_reproduces_shared_registry() {
        // the federation bit-identity contract in miniature: two nodes
        // recording into private registries, folded, must equal one
        // shared registry that saw every sample
        let shared = Registry::new();
        let n1 = Registry::new();
        let n2 = Registry::new();
        for (reg, vals) in [(&n1, [10u64, 1 << 20]), (&n2, [3, 1 << 33])] {
            for v in vals {
                reg.histogram("node.pack_stall_ns").record(v);
                shared.histogram("node.pack_stall_ns").record(v);
            }
            reg.counter("node.tasks_done").inc();
            shared.counter("node.tasks_done").inc();
            reg.gauge("node.pipelines").set(4);
        }
        shared.gauge("node.pipelines").set(4);
        let merged = Registry::new();
        Snapshot::from_registry(&n1).merge_into(&merged);
        Snapshot::from_registry(&n2).merge_into(&merged);
        assert_eq!(merged.render(), shared.render());
    }

    #[test]
    fn histogram_snapshot_matches_records() {
        let h = Histogram::new();
        for v in [1u64, 3, 1024] {
            h.record(v);
        }
        let buckets = h.bucket_counts();
        assert_eq!(buckets[0], 1); // 1
        assert_eq!(buckets[1], 1); // 3
        assert_eq!(buckets[10], 1); // 1024
        assert_eq!(h.sum(), 1028);
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn histogram_zero_value_safe() {
        let h = Histogram::new();
        h.record(0); // clamps to bucket 0
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn registry_shares_instances() {
        let r = Registry::new();
        r.counter("jobs").inc();
        r.counter("jobs").inc();
        assert_eq!(r.counter("jobs").get(), 2);
        r.histogram("lat").record(100);
        let text = r.render();
        assert!(text.contains("counter jobs 2"));
        assert!(text.contains("hist lat count=1"));
    }

    #[test]
    fn gauge_set_overwrites_and_renders() {
        let r = Registry::new();
        r.gauge("jse.jobs_in_flight").set(3);
        r.gauge("jse.jobs_in_flight").set(7);
        assert_eq!(r.gauge("jse.jobs_in_flight").get(), 7);
        r.gauge("jse.jobs_queued").set(0);
        let text = r.render();
        assert!(text.contains("gauge jse.jobs_in_flight 7"), "{text}");
        assert!(text.contains("gauge jse.jobs_queued 0"), "{text}");
    }

    #[test]
    fn render_order_is_deterministic_and_sorted() {
        // regression test for snapshot ordering: names must come out
        // sorted (BTreeMap order) no matter the registration order
        let r = Registry::new();
        for name in ["z.last", "a.first", "m.middle"] {
            r.counter(name).inc();
        }
        r.gauge("g.two").set(2);
        r.gauge("g.one").set(1);
        let text = r.render();
        let names: Vec<&str> = text
            .lines()
            .filter_map(|l| l.split_whitespace().nth(1))
            .collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted, "render must list names sorted: {text}");
        assert_eq!(text, r.render(), "repeat renders must be identical");
    }

    #[test]
    fn registered_names_are_sorted_and_unique() {
        let names = super::names::REGISTERED;
        let mut sorted: Vec<&str> = names.to_vec();
        sorted.sort();
        sorted.dedup();
        assert_eq!(names, sorted.as_slice(), "REGISTERED must be sorted+unique");
    }

    #[test]
    fn registry_concurrent() {
        let r = std::sync::Arc::new(Registry::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    r.counter("n").inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("n").get(), 8000);
    }
}
