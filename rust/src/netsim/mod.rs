//! Network simulation substrate.
//!
//! The paper's Fig 7 crossover and its §7 GridFTP plans are both
//! consequences of *how long bytes take to move*: a RTT-bound TCP stream
//! over a WAN is slow regardless of raw bandwidth (§3: "even the fastest
//! global networks are a problem due to the large acknowledgment time"),
//! and striping over multiple streams recovers the window-limited loss
//! (ref [12]). This module models exactly that:
//!
//! - [`Link`]: latency + bandwidth + TCP window per path
//! - [`tcp_throughput`]: single-stream throughput = min(bandwidth,
//!   window/RTT) — the classic bandwidth-delay-product limit
//! - [`transfer_time`]: startup (handshake) + bytes/effective-rate, with
//!   multi-stream striping and per-stream diminishing returns
//! - [`Topology`]: named hosts + per-pair links (LAN/WAN presets)

pub mod link;
pub mod topology;

pub use link::{
    disrupted_transfer_time, tcp_throughput, transfer_time, Link, LinkDisruption,
    TransferSpec,
};
pub use topology::Topology;
