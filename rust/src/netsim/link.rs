//! Link model + TCP transfer timing.

use crate::util::ByteSize;

/// A directed network path between two hosts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    /// one-way latency in seconds (RTT = 2 * latency)
    pub latency_s: f64,
    /// raw path bandwidth in bytes/second
    pub bandwidth_bps: f64,
    /// TCP window (socket buffer) in bytes — the paper's ref [12] point:
    /// default buffers cripple WAN transfers
    pub tcp_window: f64,
}

impl Link {
    /// 100 Mb/s fast Ethernet LAN (the paper's testbed, §6).
    pub fn lan_fast_ethernet() -> Link {
        Link {
            latency_s: 0.0001,             // 0.1 ms
            bandwidth_bps: 12_500_000.0,   // 100 Mb/s
            tcp_window: 64.0 * 1024.0,
        }
    }

    /// Gigabit LAN.
    pub fn lan_gigabit() -> Link {
        Link {
            latency_s: 0.00005,
            bandwidth_bps: 125_000_000.0,
            tcp_window: 256.0 * 1024.0,
        }
    }

    /// Trans-continental WAN: high bandwidth but 50 ms one-way latency and
    /// a default 64 KiB window — the configuration [12] shows is
    /// window-starved.
    pub fn wan_default_window() -> Link {
        Link {
            latency_s: 0.05,
            bandwidth_bps: 125_000_000.0, // 1 Gb/s path
            tcp_window: 64.0 * 1024.0,
        }
    }

    /// Same WAN with a tuned window (bandwidth-delay product).
    pub fn wan_tuned_window() -> Link {
        let mut l = Link::wan_default_window();
        l.tcp_window = l.bandwidth_bps * (2.0 * l.latency_s);
        l
    }

    /// Localhost / same-machine "link" (disk-to-disk copy).
    pub fn local() -> Link {
        Link {
            latency_s: 1e-6,
            bandwidth_bps: 400_000_000.0, // ~disk copy rate of the era x margin
            tcp_window: 1e9,
        }
    }

    pub fn rtt(&self) -> f64 {
        2.0 * self.latency_s
    }
}

/// Parameters of one logical transfer.
#[derive(Debug, Clone, Copy)]
pub struct TransferSpec {
    pub bytes: ByteSize,
    /// number of parallel TCP streams (GridFTP striping; 1 = plain GASS)
    pub streams: u32,
}

impl TransferSpec {
    pub fn single(bytes: ByteSize) -> Self {
        TransferSpec { bytes, streams: 1 }
    }
}

/// Single-stream steady-state TCP throughput on `link`:
/// min(raw bandwidth, window / RTT).
pub fn tcp_throughput(link: &Link) -> f64 {
    let rtt = link.rtt().max(1e-9);
    link.bandwidth_bps.min(link.tcp_window / rtt)
}

/// Aggregate throughput of `n` parallel streams: each stream gets its own
/// window (so n*window/RTT) but they share the raw path bandwidth, and
/// each extra stream pays a small coordination tax (stripe reassembly,
/// observed in [12] as sub-linear scaling near saturation).
pub fn multi_stream_throughput(link: &Link, streams: u32) -> f64 {
    let n = streams.max(1) as f64;
    let per_stream = tcp_throughput(link);
    let striped = n * per_stream;
    let efficiency = 1.0 / (1.0 + 0.02 * (n - 1.0));
    (striped * efficiency).min(link.bandwidth_bps)
}

/// What faultline injected into one transfer attempt on a link. Pure
/// data (no clocks, no randomness): the *decision* is made by
/// `faultline::FaultPlan`; this module only prices the consequence, so
/// netsim stays inside the determinism lint's strict set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LinkDisruption {
    /// healthy attempt
    None,
    /// transient congestion: transfer takes `factor` times as long
    DelaySpike(f64),
    /// the attempt is lost mid-flight (retryable)
    Drop,
    /// the path is partitioned: this and every later attempt fails
    Partitioned,
}

impl LinkDisruption {
    /// Does this disruption lose the attempt outright?
    pub fn severs(&self) -> bool {
        matches!(self, LinkDisruption::Drop | LinkDisruption::Partitioned)
    }
}

/// [`transfer_time`] under a disruption: `None` when the attempt never
/// completes (drop/partition — the caller decides whether to retry),
/// otherwise the modelled time scaled by any delay spike. A dropped
/// attempt still *spent* wall clock before failing; callers charge
/// [`transfer_time`] for it separately if they model that cost.
pub fn disrupted_transfer_time(
    link: &Link,
    spec: &TransferSpec,
    disruption: LinkDisruption,
) -> Option<f64> {
    match disruption {
        LinkDisruption::None => Some(transfer_time(link, spec)),
        LinkDisruption::DelaySpike(f) => Some(transfer_time(link, spec) * f.max(1.0)),
        LinkDisruption::Drop | LinkDisruption::Partitioned => None,
    }
}

/// Wall-clock seconds for a transfer: connection setup (1.5 RTT TCP
/// handshake + control channel) once, plus payload over the aggregate
/// stream rate. GridFTP's stripes share one control channel, so setup does
/// not multiply with streams. A zero-byte transfer pays the same setup
/// and nothing else — it used to short-circuit to a bare RTT, which
/// made the cost model discontinuous at 0 bytes (an empty transfer was
/// *cheaper* than the setup every 1-byte transfer paid).
pub fn transfer_time(link: &Link, spec: &TransferSpec) -> f64 {
    let setup = 1.5 * link.rtt();
    if spec.bytes == ByteSize::ZERO {
        return setup;
    }
    let rate = multi_stream_throughput(link, spec.streams);
    setup + spec.bytes.as_f64() / rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lan_is_bandwidth_limited() {
        let l = Link::lan_fast_ethernet();
        // window/RTT = 64KiB / 0.2ms = ~327 MB/s >> 12.5 MB/s raw
        assert!((tcp_throughput(&l) - 12_500_000.0).abs() < 1.0);
    }

    #[test]
    fn wan_default_is_window_limited() {
        let l = Link::wan_default_window();
        // 64 KiB / 100 ms = 655 KB/s << 125 MB/s raw
        let t = tcp_throughput(&l);
        assert!(t < 1_000_000.0, "throughput {t}");
    }

    #[test]
    fn tuned_window_restores_wan_bandwidth() {
        let l = Link::wan_tuned_window();
        assert!((tcp_throughput(&l) - l.bandwidth_bps).abs() < 1.0);
    }

    #[test]
    fn streams_scale_until_saturation() {
        let l = Link::wan_default_window();
        let t1 = multi_stream_throughput(&l, 1);
        let t4 = multi_stream_throughput(&l, 4);
        let t16 = multi_stream_throughput(&l, 16);
        assert!(t4 > 3.0 * t1, "t4 {t4} vs t1 {t1}");
        assert!(t16 > t4);
        assert!(t16 <= l.bandwidth_bps);
        // on a LAN (already bandwidth-limited) streams gain nothing
        let lan = Link::lan_fast_ethernet();
        let l1 = multi_stream_throughput(&lan, 1);
        let l8 = multi_stream_throughput(&lan, 8);
        assert!((l8 - l1).abs() / l1 < 0.01);
    }

    #[test]
    fn transfer_time_monotone_in_bytes() {
        let l = Link::lan_fast_ethernet();
        let t1 = transfer_time(&l, &TransferSpec::single(ByteSize::mb(1)));
        let t2 = transfer_time(&l, &TransferSpec::single(ByteSize::mb(2)));
        assert!(t2 > t1);
        // 125 MB over fast ethernet ~ 10 s
        let t =
            transfer_time(&l, &TransferSpec::single(ByteSize(125_000_000)));
        assert!((t - 10.0).abs() < 0.1, "t {t}");
    }

    #[test]
    fn transfer_time_decreases_with_streams_on_wan() {
        let l = Link::wan_default_window();
        let one = transfer_time(
            &l,
            &TransferSpec { bytes: ByteSize::mb(100), streams: 1 },
        );
        let eight = transfer_time(
            &l,
            &TransferSpec { bytes: ByteSize::mb(100), streams: 8 },
        );
        assert!(eight < one / 4.0, "8-stream {eight} vs 1-stream {one}");
    }

    #[test]
    fn disruptions_price_correctly() {
        let l = Link::lan_fast_ethernet();
        let spec = TransferSpec::single(ByteSize::mb(1));
        let base = transfer_time(&l, &spec);
        assert_eq!(
            disrupted_transfer_time(&l, &spec, LinkDisruption::None),
            Some(base)
        );
        let spiked = disrupted_transfer_time(&l, &spec, LinkDisruption::DelaySpike(4.0))
            .unwrap();
        assert!((spiked - 4.0 * base).abs() < 1e-9);
        assert_eq!(disrupted_transfer_time(&l, &spec, LinkDisruption::Drop), None);
        assert_eq!(
            disrupted_transfer_time(&l, &spec, LinkDisruption::Partitioned),
            None
        );
        assert!(LinkDisruption::Drop.severs());
        assert!(LinkDisruption::Partitioned.severs());
        assert!(!LinkDisruption::None.severs());
        assert!(!LinkDisruption::DelaySpike(2.0).severs());
    }

    #[test]
    fn empty_transfer_costs_connection_setup() {
        let l = Link::wan_default_window();
        assert!((transfer_time(&l, &TransferSpec::single(ByteSize::ZERO))
            - 1.5 * l.rtt())
        .abs()
            < 1e-12);
        // the model is continuous at zero: one byte costs setup plus an
        // infinitesimal payload term, never less than the empty transfer
        let one = transfer_time(&l, &TransferSpec::single(ByteSize(1)));
        let zero = transfer_time(&l, &TransferSpec::single(ByteSize::ZERO));
        assert!(one >= zero);
        assert!(one - zero < 1e-3, "payload term for 1 byte is tiny");
    }
}
