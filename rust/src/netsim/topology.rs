//! Cluster topology: named hosts and the links between them.
//!
//! The paper's testbed is two machines (`gandalf`, `hobbit`) on fast
//! Ethernet plus a job-submit server; our topology generalises to N hosts
//! with per-pair link overrides (so WAN-separated sites can be modelled,
//! which §3 discusses and Ext-A measures).

use crate::netsim::link::Link;
use std::collections::BTreeMap;

/// Named hosts + default link + per-pair overrides.
#[derive(Debug, Clone)]
pub struct Topology {
    hosts: Vec<String>,
    default_link: Link,
    overrides: BTreeMap<(String, String), Link>,
    /// the host that runs the JSE / job-submit server
    leader: String,
}

impl Topology {
    pub fn new(leader: &str, default_link: Link) -> Self {
        Topology {
            hosts: vec![leader.to_string()],
            default_link,
            overrides: BTreeMap::new(),
            leader: leader.to_string(),
        }
    }

    /// The paper's testbed: leader + gandalf + hobbit on fast Ethernet.
    pub fn paper_testbed() -> Self {
        let mut t = Topology::new("jse", Link::lan_fast_ethernet());
        t.add_host("gandalf");
        t.add_host("hobbit");
        t
    }

    /// A uniform LAN cluster of `n` workers named node0..n-1.
    pub fn lan_cluster(n: usize, link: Link) -> Self {
        let mut t = Topology::new("jse", link);
        for i in 0..n {
            t.add_host(&format!("node{i}"));
        }
        t
    }

    pub fn add_host(&mut self, name: &str) {
        if !self.hosts.iter().any(|h| h == name) {
            self.hosts.push(name.to_string());
        }
    }

    pub fn set_link(&mut self, a: &str, b: &str, link: Link) {
        self.overrides.insert((a.to_string(), b.to_string()), link);
        self.overrides.insert((b.to_string(), a.to_string()), link);
    }

    /// Link between two hosts (same host = local copy).
    pub fn link(&self, a: &str, b: &str) -> Link {
        if a == b {
            return Link::local();
        }
        self.overrides
            .get(&(a.to_string(), b.to_string()))
            .copied()
            .unwrap_or(self.default_link)
    }

    pub fn leader(&self) -> &str {
        &self.leader
    }

    pub fn hosts(&self) -> &[String] {
        &self.hosts
    }

    /// Worker hosts (everything but the leader).
    pub fn workers(&self) -> Vec<String> {
        self.hosts
            .iter()
            .filter(|h| *h != &self.leader)
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_shape() {
        let t = Topology::paper_testbed();
        assert_eq!(t.leader(), "jse");
        assert_eq!(t.workers(), vec!["gandalf", "hobbit"]);
    }

    #[test]
    fn same_host_is_local() {
        let t = Topology::paper_testbed();
        let l = t.link("hobbit", "hobbit");
        assert!(l.bandwidth_bps > Link::lan_fast_ethernet().bandwidth_bps);
    }

    #[test]
    fn overrides_are_symmetric() {
        let mut t = Topology::lan_cluster(3, Link::lan_fast_ethernet());
        t.set_link("node0", "node2", Link::wan_default_window());
        assert_eq!(t.link("node0", "node2"), Link::wan_default_window());
        assert_eq!(t.link("node2", "node0"), Link::wan_default_window());
        assert_eq!(t.link("node0", "node1"), Link::lan_fast_ethernet());
    }

    #[test]
    fn add_host_dedupes() {
        let mut t = Topology::new("jse", Link::lan_gigabit());
        t.add_host("a");
        t.add_host("a");
        assert_eq!(t.hosts().len(), 2);
    }
}
