//! `qcache` — the repeated-analysis subsystem: query-result caching,
//! in-flight scan sharing, and per-brick partial memoization.
//!
//! The paper's operating model is many users submitting selections whose
//! per-node results the JSE merges centrally; interactive-analysis
//! traffic (DIAL-style) re-runs the same and near-same selections
//! constantly. Without this subsystem every submission recomputes every
//! brick. With it, repeated and overlapping queries stop costing compute
//! at all, in three layers:
//!
//! 1. **Query fingerprinting.** The submitted filter is parsed,
//!    typechecked and rewritten into canonical form
//!    ([`crate::filterexpr::canon`] — constant folding, commutative
//!    operand ordering, double-negation elimination; all strictly
//!    semantics-preserving), then hashed together with the histogram
//!    spec (feature count, bin count, per-feature ranges) and the
//!    dataset id into a *query fingerprint* ([`query_fingerprint`]).
//!    Hashing the brick **content-epoch vector** on top yields the
//!    *full-result key* ([`full_fingerprint`]). Epochs live in the
//!    catalogue ([`crate::catalog::Catalog::bump_content_epoch`]) and
//!    move **only when brick data changes** — re-replication,
//!    rebalancing and membership churn rewrite holder lists without
//!    touching them, so placement churn can never invalidate a cache
//!    entry.
//! 2. **Full-result cache + scan sharing.** A byte-budgeted LRU maps
//!    full-result keys to merged histograms (plus the job counters
//!    needed to reconstitute an outcome): a repeated query is served at
//!    admission without dispatching a single task. An **in-flight
//!    table** handles the window before a result exists: a job whose
//!    key matches a *running* job attaches as a subscriber and receives
//!    the same bit-identical merged result when the primary's runner
//!    seals. Cancelling the primary promotes a subscriber to recompute;
//!    node death and failover happen inside the primary's runner, so
//!    subscribers simply stay attached.
//! 3. **Per-brick partial memoization.** Whole-brick `TaskDone` replies
//!    are harvested as `(query fingerprint, brick, epoch) → partial
//!    histogram` entries. An incoming job whose full key misses plans
//!    tasks **only for bricks without a valid partial**; memoized
//!    partials are pre-merged into the runner's outcome. Because
//!    histogram bins are integer event counts (exact in f32), the
//!    memoized-plus-fresh merge is bit-identical to a cold recompute
//!    regardless of merge order.
//!
//! The invalidation contract, in one line: **a cache entry dies only
//! when a brick it covers changes content (epoch bump) or the LRU
//! evicts it under byte pressure — never because data moved between
//! nodes.**
//!
//! Surfaces: `GET /cache` (stats) and `POST /cache/flush` on the portal,
//! `geps cache-stats` / `geps cache-flush` on the CLI, and the
//! `qcache.hits_full` / `qcache.hits_partial` / `qcache.shared_jobs` /
//! `qcache.evictions` counters plus the `qcache.bytes` gauge on
//! `GET /metrics`. The JSE admission path drives the cache (see
//! [`crate::jse`]); this module is pure bookkeeping and is safe to call
//! from any thread.

use crate::brick::BrickId;
use crate::events::{FeatureId, NUM_FEATURES};
use crate::filterexpr::ast::Expr;
use crate::filterexpr::canon;
use crate::metrics::Registry;
use crate::util::hash::xxhash64;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Fingerprint hash seeds (distinct per layer so a query fingerprint can
/// never collide with a full key built from it by construction).
const SEED_QUERY: u64 = 0x9E75_0000_C0DE_0001;
const SEED_FULL: u64 = 0x9E75_0000_C0DE_0002;

/// Hash a **canonicalized** filter AST together with the histogram spec
/// and dataset id into the query fingerprint (layer 1). Two submissions
/// share a fingerprint iff they request the same selection over the
/// same dataset under the same histogram layout.
pub fn query_fingerprint(canonical: &Expr, dataset: u32) -> u64 {
    let mut bytes = canon::encode(canonical);
    // Histogram spec: feature count, bin count, per-feature [lo, hi) —
    // any change to the layout changes the result, so it keys the
    // cache. NOTE: the bin count hashed here is the build-time default
    // (what reference manifests are written with), not the live
    // engine manifest's — adequate for this in-process cache because
    // one process runs one manifest, but cross-restart persistence
    // (ROADMAP follow-on) must re-key on the loaded manifest's
    // hist_bins before entries may outlive the process.
    bytes.push(0xFE);
    bytes.extend_from_slice(&(NUM_FEATURES as u32).to_le_bytes());
    bytes.extend_from_slice(
        &(crate::runtime::manifest::DEFAULT_HIST_BINS as u32).to_le_bytes(),
    );
    for r in FeatureId::ranges_flat() {
        bytes.extend_from_slice(&r.to_bits().to_le_bytes());
    }
    bytes.push(0xFD);
    bytes.extend_from_slice(&dataset.to_le_bytes());
    xxhash64(&bytes, SEED_QUERY)
}

/// Hash a query fingerprint together with the dataset's brick
/// content-epoch vector into the full-result key (layer 2). Bumping any
/// brick's epoch changes the key; holder rewrites do not.
pub fn full_fingerprint(qfp: u64, epochs: &[(BrickId, u64)]) -> u64 {
    let mut es: Vec<(BrickId, u64)> = epochs.to_vec();
    es.sort();
    let mut bytes = Vec::with_capacity(8 + es.len() * 16);
    bytes.extend_from_slice(&qfp.to_le_bytes());
    for (b, e) in es {
        bytes.extend_from_slice(&b.dataset.to_le_bytes());
        bytes.extend_from_slice(&b.seq.to_le_bytes());
        bytes.extend_from_slice(&e.to_le_bytes());
    }
    xxhash64(&bytes, SEED_FULL)
}

/// Decode a wire histogram payload (LE f32 bytes) into bin values.
/// Trailing ragged bytes are ignored, mirroring the JSE merge.
pub fn decode_hist(raw: &[u8]) -> Vec<f32> {
    raw.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// A cached merged job result (layer 2 value).
#[derive(Debug, Clone, PartialEq)]
pub struct CachedResult {
    /// merged (F * bins) histogram of selected events
    pub histogram: Vec<f32>,
    pub events_in: u64,
    pub events_selected: u64,
    pub result_bytes: u64,
    pub tasks_completed: usize,
}

impl CachedResult {
    fn cost(&self) -> usize {
        self.histogram.len() * 4 + 64
    }
}

/// A memoized per-brick partial (layer 3 value): exactly what the
/// brick's whole-range `TaskDone` carried.
#[derive(Debug, Clone, PartialEq)]
pub struct PartialResult {
    pub histogram: Vec<f32>,
    pub events_in: u64,
    pub events_selected: u64,
    pub result_bytes: u64,
}

impl PartialResult {
    fn cost(&self) -> usize {
        self.histogram.len() * 4 + 64
    }
}

/// Outcome of [`QCache::attach`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Attach {
    /// No identical job is running: the caller owns the computation
    /// (and must later settle via [`QCache::take_subscribers`]).
    Primary,
    /// An identical job is already running: the caller was registered
    /// as a subscriber and will be handed the primary's result.
    Subscriber,
}

/// Byte-budgeted LRU keyed by `K`. Hand-rolled over two BTreeMaps (no
/// external deps): `map` holds the values, `order` is the
/// access-tick → key recency index eviction walks from the front.
struct Lru<K: Ord + Clone, V> {
    map: BTreeMap<K, Slot<V>>,
    order: BTreeMap<u64, K>,
    bytes: usize,
    budget: usize,
    next_tick: u64,
}

struct Slot<V> {
    value: V,
    tick: u64,
    cost: usize,
}

impl<K: Ord + Clone, V> Lru<K, V> {
    fn new(budget: usize) -> Self {
        Lru {
            map: BTreeMap::new(),
            order: BTreeMap::new(),
            bytes: 0,
            budget,
            next_tick: 0,
        }
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    /// Lookup + touch (moves the entry to most-recently-used).
    fn get(&mut self, k: &K) -> Option<&V> {
        let tick = self.next_tick;
        let slot = self.map.get_mut(k)?;
        self.order.remove(&slot.tick);
        slot.tick = tick;
        self.order.insert(tick, k.clone());
        self.next_tick += 1;
        Some(&slot.value)
    }

    /// Insert (replacing any previous value) and evict least-recently
    /// used entries until the byte budget holds. Returns how many
    /// entries were evicted. The entry just inserted is never evicted —
    /// a single oversized result simply occupies the whole budget.
    fn insert(&mut self, k: K, value: V, cost: usize) -> usize {
        if let Some(old) = self.map.remove(&k) {
            self.order.remove(&old.tick);
            self.bytes -= old.cost;
        }
        let tick = self.next_tick;
        self.next_tick += 1;
        self.bytes += cost;
        self.map.insert(k.clone(), Slot { value, tick, cost });
        self.order.insert(tick, k);
        let mut evicted = 0;
        while self.bytes > self.budget && self.map.len() > 1 {
            let Some((&oldest, _)) = self.order.first_key_value() else {
                break;
            };
            if oldest == tick {
                break; // only the newcomer left over budget
            }
            let key = self.order.remove(&oldest).expect("index entry");
            if let Some(slot) = self.map.remove(&key) {
                self.bytes -= slot.cost;
            }
            evicted += 1;
        }
        evicted
    }

    fn clear(&mut self) -> usize {
        let n = self.map.len();
        self.map.clear();
        self.order.clear();
        self.bytes = 0;
        n
    }
}

/// One running computation and the jobs sharing it.
#[derive(Debug, Clone)]
struct Inflight {
    primary: u64,
    subscribers: Vec<u64>,
}

struct Inner {
    full: Lru<u64, CachedResult>,
    partial: Lru<(u64, BrickId, u64), PartialResult>,
    inflight: BTreeMap<u64, Inflight>,
    // cumulative counters (mirrored to the metrics registry when set)
    hits_full: u64,
    misses_full: u64,
    hits_partial: u64,
    misses_partial: u64,
    shared_jobs: u64,
    evictions: u64,
    flushes: u64,
}

/// Point-in-time cache statistics (the portal's `GET /cache`).
#[derive(Debug, Clone, PartialEq)]
pub struct QCacheStats {
    pub full_entries: usize,
    pub partial_entries: usize,
    pub inflight: usize,
    pub bytes: u64,
    pub budget_bytes: u64,
    pub hits_full: u64,
    pub misses_full: u64,
    pub hits_partial: u64,
    pub misses_partial: u64,
    pub shared_jobs: u64,
    pub evictions: u64,
    pub flushes: u64,
}

/// Cache sizing knobs.
#[derive(Debug, Clone)]
pub struct QCacheConfig {
    /// byte budget of the full-result LRU (layer 2)
    pub full_budget_bytes: usize,
    /// byte budget of the per-brick partial LRU (layer 3)
    pub partial_budget_bytes: usize,
}

impl Default for QCacheConfig {
    fn default() -> Self {
        QCacheConfig {
            full_budget_bytes: 32 << 20,
            partial_budget_bytes: 32 << 20,
        }
    }
}

/// The query-result cache. Thread-safe (one mutex around the
/// bookkeeping; values are cloned out), shared as an `Arc` between the
/// JSE event loop (admission, harvest, settlement) and the portal
/// (stats, flush).
pub struct QCache {
    inner: Mutex<Inner>,
    cfg: QCacheConfig,
    metrics: OnceLock<Arc<Registry>>,
    /// flight recorder ([`crate::obs`]): scan-sharing attachments are
    /// journalled under the subscribing job's id
    recorder: OnceLock<Arc<crate::obs::Recorder>>,
}

impl Default for QCache {
    fn default() -> Self {
        QCache::new(QCacheConfig::default())
    }
}

impl QCache {
    pub fn new(cfg: QCacheConfig) -> Self {
        QCache {
            inner: Mutex::new(Inner {
                full: Lru::new(cfg.full_budget_bytes.max(1)),
                partial: Lru::new(cfg.partial_budget_bytes.max(1)),
                inflight: BTreeMap::new(),
                hits_full: 0,
                misses_full: 0,
                hits_partial: 0,
                misses_partial: 0,
                shared_jobs: 0,
                evictions: 0,
                flushes: 0,
            }),
            cfg,
            metrics: OnceLock::new(),
            recorder: OnceLock::new(),
        }
    }

    /// Attach a metrics registry; counters/gauge mirror every mutation.
    pub fn set_metrics(&self, metrics: Arc<Registry>) {
        let _ = self.metrics.set(metrics);
    }

    /// Attach the flight recorder: scan-sharing subscriptions become
    /// per-job `qcache_subscribed` trace events.
    pub fn set_recorder(&self, recorder: Arc<crate::obs::Recorder>) {
        let _ = self.recorder.set(recorder);
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // a panic while holding this lock leaves only LRU bookkeeping
        // behind; the cache stays usable (worst case: a stale entry is
        // later overwritten by an identical recompute)
        match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    fn publish_bytes(&self, inner: &Inner) {
        if let Some(m) = self.metrics.get() {
            m.gauge("qcache.bytes")
                .set((inner.full.bytes + inner.partial.bytes) as u64);
        }
    }

    fn bump(&self, name: &str, n: u64) {
        if n > 0 {
            if let Some(m) = self.metrics.get() {
                m.counter(name).add(n);
            }
        }
    }

    /// Layer 2 lookup: a hit returns the merged result to serve at
    /// admission time (and counts toward `qcache.hits_full`).
    pub fn lookup_full(&self, key: u64) -> Option<CachedResult> {
        let mut inner = self.lock();
        let hit = inner.full.get(&key).cloned();
        match &hit {
            Some(_) => inner.hits_full += 1,
            None => inner.misses_full += 1,
        }
        drop(inner);
        if hit.is_some() {
            self.bump("qcache.hits_full", 1);
        }
        hit
    }

    /// Publish a sealed job's merged result under its full key.
    pub fn insert_full(&self, key: u64, result: CachedResult) {
        let cost = result.cost();
        let mut inner = self.lock();
        let evicted = inner.full.insert(key, result, cost);
        inner.evictions += evicted as u64;
        self.publish_bytes(&inner);
        drop(inner);
        self.bump("qcache.evictions", evicted as u64);
    }

    /// Scan sharing: register `job` against `key`. If nothing identical
    /// is running (or `job` is already the designated primary, as after
    /// a promotion) the caller computes; otherwise it subscribes.
    pub fn attach(&self, key: u64, job: u64) -> Attach {
        let mut guard = self.lock();
        let inner = &mut *guard;
        let mut newly_shared = false;
        let out = match inner.inflight.entry(key) {
            std::collections::btree_map::Entry::Vacant(v) => {
                v.insert(Inflight {
                    primary: job,
                    subscribers: Vec::new(),
                });
                Attach::Primary
            }
            std::collections::btree_map::Entry::Occupied(mut o) => {
                let e = o.get_mut();
                if e.primary == job {
                    Attach::Primary
                } else {
                    if !e.subscribers.contains(&job) {
                        e.subscribers.push(job);
                        newly_shared = true;
                    }
                    Attach::Subscriber
                }
            }
        };
        if newly_shared {
            inner.shared_jobs += 1;
        }
        drop(guard);
        if newly_shared {
            self.bump("qcache.shared_jobs", 1);
            if let Some(o) = self.recorder.get() {
                o.record(
                    job,
                    "qcache_subscribed",
                    job.to_string(),
                    "riding an identical in-flight job",
                );
            }
        }
        out
    }

    /// Settlement: the primary sealed (Done, Failed, or is being
    /// cancelled). Removes the in-flight entry and returns the
    /// subscribers awaiting its result. Guarded on the primary id so a
    /// stale caller can never steal a promoted entry's subscribers.
    pub fn take_subscribers(&self, key: u64, primary: u64) -> Vec<u64> {
        let mut inner = self.lock();
        let owned = inner
            .inflight
            .get(&key)
            .map(|e| e.primary == primary)
            .unwrap_or(false);
        if !owned {
            return Vec::new();
        }
        inner
            .inflight
            .remove(&key)
            .map(|e| e.subscribers)
            .unwrap_or_default()
    }

    /// A subscriber left on its own (portal cancel / explicit failure):
    /// detach it from the key it follows. Returns true if it was
    /// subscribed there.
    pub fn detach_subscriber(&self, key: u64, job: u64) -> bool {
        let mut inner = self.lock();
        if let Some(e) = inner.inflight.get_mut(&key) {
            if let Some(pos) = e.subscribers.iter().position(|j| *j == job)
            {
                e.subscribers.remove(pos);
                return true;
            }
        }
        false
    }

    /// Layer 3 lookup (counts toward `qcache.hits_partial` on hit).
    pub fn lookup_partial(
        &self,
        qfp: u64,
        brick: BrickId,
        epoch: u64,
    ) -> Option<PartialResult> {
        let mut inner = self.lock();
        let hit = inner.partial.get(&(qfp, brick, epoch)).cloned();
        match &hit {
            Some(_) => inner.hits_partial += 1,
            None => inner.misses_partial += 1,
        }
        drop(inner);
        if hit.is_some() {
            self.bump("qcache.hits_partial", 1);
        }
        hit
    }

    /// Harvest a whole-brick `TaskDone` into the partial store.
    pub fn insert_partial(
        &self,
        qfp: u64,
        brick: BrickId,
        epoch: u64,
        result: PartialResult,
    ) {
        let cost = result.cost();
        let mut inner = self.lock();
        let evicted =
            inner.partial.insert((qfp, brick, epoch), result, cost);
        inner.evictions += evicted as u64;
        self.publish_bytes(&inner);
        drop(inner);
        self.bump("qcache.evictions", evicted as u64);
    }

    /// Drop every cached result (full + partial). In-flight sharing
    /// state is *not* touched: running jobs still settle with their
    /// subscribers. Returns the number of entries dropped.
    pub fn flush(&self) -> usize {
        let mut inner = self.lock();
        let n = inner.full.clear() + inner.partial.clear();
        inner.flushes += 1;
        self.publish_bytes(&inner);
        n
    }

    pub fn stats(&self) -> QCacheStats {
        let inner = self.lock();
        QCacheStats {
            full_entries: inner.full.len(),
            partial_entries: inner.partial.len(),
            inflight: inner.inflight.len(),
            bytes: (inner.full.bytes + inner.partial.bytes) as u64,
            budget_bytes: (self.cfg.full_budget_bytes
                + self.cfg.partial_budget_bytes)
                as u64,
            hits_full: inner.hits_full,
            misses_full: inner.misses_full,
            hits_partial: inner.hits_partial,
            misses_partial: inner.misses_partial,
            shared_jobs: inner.shared_jobs,
            evictions: inner.evictions,
            flushes: inner.flushes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filterexpr::{canonicalize, parse};

    fn fp(src: &str, dataset: u32) -> u64 {
        query_fingerprint(&canonicalize(&parse(src).unwrap()), dataset)
    }

    #[test]
    fn fingerprints_collapse_rewrites_and_separate_selections() {
        assert_eq!(
            fp("met > 30 && n_tracks >= 2", 1),
            fp("n_tracks>=2 && met>30", 1)
        );
        assert_ne!(fp("met > 30", 1), fp("met > 31", 1));
        assert_ne!(fp("met > 30", 1), fp("met > 30", 2), "dataset keyed");
    }

    #[test]
    fn full_key_tracks_epochs_not_order() {
        let q = fp("met > 1", 1);
        let b0 = BrickId::new(1, 0);
        let b1 = BrickId::new(1, 1);
        let k = full_fingerprint(q, &[(b0, 1), (b1, 1)]);
        assert_eq!(
            k,
            full_fingerprint(q, &[(b1, 1), (b0, 1)]),
            "row order must not matter"
        );
        assert_ne!(k, full_fingerprint(q, &[(b0, 2), (b1, 1)]));
        assert_ne!(k, full_fingerprint(q, &[(b0, 1)]));
    }

    fn result(bins: usize, fill: f32) -> CachedResult {
        CachedResult {
            histogram: vec![fill; bins],
            events_in: 100,
            events_selected: 10,
            result_bytes: 1000,
            tasks_completed: 4,
        }
    }

    #[test]
    fn full_cache_hits_and_counts() {
        let q = QCache::new(QCacheConfig::default());
        assert_eq!(q.lookup_full(7), None);
        q.insert_full(7, result(8, 1.0));
        assert_eq!(q.lookup_full(7), Some(result(8, 1.0)));
        let s = q.stats();
        assert_eq!((s.hits_full, s.misses_full), (1, 1));
        assert_eq!(s.full_entries, 1);
        assert!(s.bytes > 0);
    }

    #[test]
    fn lru_evicts_oldest_under_byte_pressure() {
        // each entry costs 64*4 + 64 = 320 bytes; budget fits 2
        let q = QCache::new(QCacheConfig {
            full_budget_bytes: 700,
            partial_budget_bytes: 1,
        });
        q.insert_full(1, result(64, 1.0));
        q.insert_full(2, result(64, 2.0));
        assert!(q.lookup_full(1).is_some(), "touch 1: now MRU");
        q.insert_full(3, result(64, 3.0));
        assert_eq!(q.stats().evictions, 1);
        assert!(q.lookup_full(2).is_none(), "2 was LRU");
        assert!(q.lookup_full(1).is_some());
        assert!(q.lookup_full(3).is_some());
        // an oversized single entry still lands (occupying the budget)
        q.insert_full(9, result(4096, 9.0));
        assert!(q.lookup_full(9).is_some());
    }

    #[test]
    fn inflight_attach_subscribe_settle() {
        let q = QCache::new(QCacheConfig::default());
        assert_eq!(q.attach(5, 100), Attach::Primary);
        assert_eq!(q.attach(5, 101), Attach::Subscriber);
        assert_eq!(q.attach(5, 102), Attach::Subscriber);
        assert_eq!(q.attach(5, 101), Attach::Subscriber, "idempotent");
        assert_eq!(q.stats().shared_jobs, 2);
        // wrong primary cannot steal the entry
        assert!(q.take_subscribers(5, 101).is_empty());
        assert_eq!(q.take_subscribers(5, 100), vec![101, 102]);
        assert_eq!(q.stats().inflight, 0);
        // promotion flow: re-register with a new primary
        assert_eq!(q.attach(5, 101), Attach::Primary);
        assert_eq!(q.attach(5, 102), Attach::Subscriber);
        assert_eq!(q.attach(5, 101), Attach::Primary, "still the owner");
        assert!(q.detach_subscriber(5, 102));
        assert!(!q.detach_subscriber(5, 102));
        assert!(!q.detach_subscriber(99, 102), "unknown key");
        assert_eq!(q.take_subscribers(5, 101), Vec::<u64>::new());
    }

    #[test]
    fn partial_entries_key_on_epoch() {
        let q = QCache::new(QCacheConfig::default());
        let b = BrickId::new(1, 3);
        let p = PartialResult {
            histogram: vec![1.0; 8],
            events_in: 50,
            events_selected: 5,
            result_bytes: 500,
        };
        q.insert_partial(42, b, 1, p.clone());
        assert_eq!(q.lookup_partial(42, b, 1), Some(p));
        assert_eq!(q.lookup_partial(42, b, 2), None, "epoch bump misses");
        assert_eq!(q.lookup_partial(43, b, 1), None, "other query misses");
        assert_eq!(q.stats().hits_partial, 1);
    }

    #[test]
    fn flush_clears_results_but_not_inflight() {
        let q = QCache::new(QCacheConfig::default());
        q.insert_full(1, result(8, 1.0));
        q.insert_partial(
            2,
            BrickId::new(1, 0),
            1,
            PartialResult {
                histogram: vec![0.0; 8],
                events_in: 1,
                events_selected: 0,
                result_bytes: 0,
            },
        );
        assert_eq!(q.attach(9, 500), Attach::Primary);
        assert_eq!(q.flush(), 2);
        let s = q.stats();
        assert_eq!((s.full_entries, s.partial_entries), (0, 0));
        assert_eq!(s.bytes, 0);
        assert_eq!(s.inflight, 1, "running jobs still settle");
        assert_eq!(q.take_subscribers(9, 500), Vec::<u64>::new());
    }

    #[test]
    fn metrics_mirror() {
        let q = QCache::new(QCacheConfig::default());
        let m = Arc::new(Registry::new());
        q.set_metrics(m.clone());
        q.insert_full(1, result(8, 1.0));
        let _ = q.lookup_full(1);
        let _ = q.attach(1, 10);
        let _ = q.attach(1, 11);
        assert_eq!(m.counter("qcache.hits_full").get(), 1);
        assert_eq!(m.counter("qcache.shared_jobs").get(), 1);
        assert!(m.gauge("qcache.bytes").get() > 0);
    }
}
