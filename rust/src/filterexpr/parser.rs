//! Filter-expression parser (hand-rolled Pratt-less recursive descent —
//! the precedence ladder is fixed and shallow).

use crate::events::FeatureId;
use crate::filterexpr::ast::{BinOp, Expr, Func, UnOp};

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "filter parse error at {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Num(f64),
    Ident(String),
    Op(&'static str),
    LParen,
    RParen,
    Comma,
}

fn lex(src: &str) -> Result<Vec<(usize, Tok)>, ParseError> {
    let b = src.as_bytes();
    let mut i = 0;
    let mut out = Vec::new();
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\n' | b'\r' => i += 1,
            b'(' => {
                out.push((i, Tok::LParen));
                i += 1;
            }
            b')' => {
                out.push((i, Tok::RParen));
                i += 1;
            }
            b',' => {
                out.push((i, Tok::Comma));
                i += 1;
            }
            b'&' if b.get(i + 1) == Some(&b'&') => {
                out.push((i, Tok::Op("&&")));
                i += 2;
            }
            b'|' if b.get(i + 1) == Some(&b'|') => {
                out.push((i, Tok::Op("||")));
                i += 2;
            }
            b'>' | b'<' | b'=' | b'!' => {
                if b.get(i + 1) == Some(&b'=') {
                    let op = match c {
                        b'>' => ">=",
                        b'<' => "<=",
                        b'=' => "==",
                        _ => "!=",
                    };
                    out.push((i, Tok::Op(op)));
                    i += 2;
                } else {
                    let op = match c {
                        b'>' => ">",
                        b'<' => "<",
                        b'!' => "!",
                        _ => {
                            return Err(ParseError {
                                pos: i,
                                msg: "single '=' (use '==')".into(),
                            })
                        }
                    };
                    out.push((i, Tok::Op(op)));
                    i += 1;
                }
            }
            b'+' => {
                out.push((i, Tok::Op("+")));
                i += 1;
            }
            b'-' => {
                out.push((i, Tok::Op("-")));
                i += 1;
            }
            b'*' => {
                out.push((i, Tok::Op("*")));
                i += 1;
            }
            b'/' => {
                out.push((i, Tok::Op("/")));
                i += 1;
            }
            c if c.is_ascii_digit() || c == b'.' => {
                let start = i;
                while i < b.len()
                    && (b[i].is_ascii_digit()
                        || b[i] == b'.'
                        || b[i] == b'e'
                        || b[i] == b'E'
                        || ((b[i] == b'+' || b[i] == b'-')
                            && i > start
                            && (b[i - 1] == b'e' || b[i - 1] == b'E')))
                {
                    i += 1;
                }
                let n: f64 = src[start..i].parse().map_err(|_| ParseError {
                    pos: start,
                    msg: format!("bad number '{}'", &src[start..i]),
                })?;
                out.push((start, Tok::Num(n)));
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                while i < b.len()
                    && (b[i].is_ascii_alphanumeric() || b[i] == b'_')
                {
                    i += 1;
                }
                out.push((start, Tok::Ident(src[start..i].to_string())));
            }
            _ => {
                return Err(ParseError {
                    pos: i,
                    msg: format!("unexpected character '{}'", c as char),
                })
            }
        }
    }
    Ok(out)
}

struct P {
    toks: Vec<(usize, Tok)>,
    i: usize,
}

impl P {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i).map(|(_, t)| t)
    }

    fn pos(&self) -> usize {
        self.toks.get(self.i).map(|(p, _)| *p).unwrap_or(usize::MAX)
    }

    fn bump(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.i).map(|(_, t)| t.clone());
        if t.is_some() {
            self.i += 1;
        }
        t
    }

    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { pos: self.pos(), msg: msg.into() }
    }

    fn eat_op(&mut self, op: &str) -> bool {
        match self.peek() {
            Some(Tok::Op(o)) if *o == op => {
                self.i += 1;
                true
            }
            _ => false,
        }
    }

    fn or_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.and_expr()?;
        while self.eat_op("||") {
            let rhs = self.and_expr()?;
            lhs = Expr::Bin(BinOp::Or, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.cmp_expr()?;
        while self.eat_op("&&") {
            let rhs = self.cmp_expr()?;
            lhs = Expr::Bin(BinOp::And, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<Expr, ParseError> {
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Some(Tok::Op(">")) => BinOp::Gt,
            Some(Tok::Op(">=")) => BinOp::Ge,
            Some(Tok::Op("<")) => BinOp::Lt,
            Some(Tok::Op("<=")) => BinOp::Le,
            Some(Tok::Op("==")) => BinOp::Eq,
            Some(Tok::Op("!=")) => BinOp::Ne,
            _ => return Ok(lhs),
        };
        self.bump();
        let rhs = self.add_expr()?;
        Ok(Expr::Bin(op, Box::new(lhs), Box::new(rhs)))
    }

    fn add_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Some(Tok::Op("+")) => BinOp::Add,
                Some(Tok::Op("-")) => BinOp::Sub,
                _ => return Ok(lhs),
            };
            self.bump();
            let rhs = self.mul_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
    }

    fn mul_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.unary_expr()?;
        loop {
            let op = match self.peek() {
                Some(Tok::Op("*")) => BinOp::Mul,
                Some(Tok::Op("/")) => BinOp::Div,
                _ => return Ok(lhs),
            };
            self.bump();
            let rhs = self.unary_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
    }

    fn unary_expr(&mut self) -> Result<Expr, ParseError> {
        if self.eat_op("!") {
            let e = self.unary_expr()?;
            return Ok(Expr::Un(UnOp::Not, Box::new(e)));
        }
        if self.eat_op("-") {
            let e = self.unary_expr()?;
            return Ok(Expr::Un(UnOp::Neg, Box::new(e)));
        }
        self.primary()
    }

    fn primary(&mut self) -> Result<Expr, ParseError> {
        match self.bump() {
            Some(Tok::Num(n)) => Ok(Expr::Num(n)),
            Some(Tok::LParen) => {
                let e = self.or_expr()?;
                match self.bump() {
                    Some(Tok::RParen) => Ok(e),
                    _ => Err(self.err("expected ')'")),
                }
            }
            Some(Tok::Ident(name)) => {
                if self.peek() == Some(&Tok::LParen) {
                    // function call
                    let f = Func::by_name(&name)
                        .ok_or_else(|| self.err(format!("unknown function '{name}'")))?;
                    self.bump(); // (
                    let mut args = Vec::new();
                    if self.peek() != Some(&Tok::RParen) {
                        loop {
                            args.push(self.or_expr()?);
                            match self.bump() {
                                Some(Tok::Comma) => continue,
                                Some(Tok::RParen) => break,
                                _ => return Err(self.err("expected ',' or ')'")),
                            }
                        }
                    } else {
                        self.bump();
                    }
                    Ok(Expr::Call(f, args))
                } else if name == "true" {
                    Ok(Expr::Bool(true))
                } else if name == "false" {
                    Ok(Expr::Bool(false))
                } else {
                    let f = FeatureId::by_name(&name).ok_or_else(|| {
                        self.err(format!("unknown feature '{name}'"))
                    })?;
                    Ok(Expr::Feature(f as u16))
                }
            }
            other => Err(self.err(format!("expected expression, got {other:?}"))),
        }
    }
}

/// Parse a filter expression.
pub fn parse(src: &str) -> Result<Expr, ParseError> {
    let toks = lex(src)?;
    if toks.is_empty() {
        return Err(ParseError { pos: 0, msg: "empty expression".into() });
    }
    let mut p = P { toks, i: 0 };
    let e = p.or_expr()?;
    if p.i != p.toks.len() {
        return Err(p.err("trailing tokens"));
    }
    Ok(e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precedence() {
        // && binds tighter than ||
        let e = parse("met > 1 || met > 2 && met > 3").unwrap();
        match e {
            Expr::Bin(BinOp::Or, _, rhs) => match *rhs {
                Expr::Bin(BinOp::And, _, _) => {}
                other => panic!("rhs {other:?}"),
            },
            other => panic!("{other:?}"),
        }
        // * binds tighter than +
        let e = parse("met + 2 * 3 > 0").unwrap();
        match e {
            Expr::Bin(BinOp::Gt, lhs, _) => match *lhs {
                Expr::Bin(BinOp::Add, _, rhs) => match *rhs {
                    Expr::Bin(BinOp::Mul, _, _) => {}
                    other => panic!("{other:?}"),
                },
                other => panic!("{other:?}"),
            },
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parens_override() {
        let e = parse("(met + 2) * 3 > 0").unwrap();
        match e {
            Expr::Bin(BinOp::Gt, lhs, _) => {
                assert!(matches!(*lhs, Expr::Bin(BinOp::Mul, _, _)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn functions_parse() {
        let e = parse("abs(max_abs_eta - 2.5) < min(1.0, ht_frac)").unwrap();
        assert!(e.check().is_ok());
    }

    #[test]
    fn unary_ops() {
        assert!(parse("!(met > 3)").unwrap().check().is_ok());
        assert!(parse("-met < -1").unwrap().check().is_ok());
    }

    #[test]
    fn scientific_notation() {
        let e = parse("sum_pt > 1.5e2").unwrap();
        match e {
            Expr::Bin(_, _, rhs) => assert_eq!(*rhs, Expr::Num(150.0)),
            _ => panic!(),
        }
    }

    #[test]
    fn all_feature_names_resolve() {
        for f in crate::events::FeatureId::ALL {
            let src = format!("{} >= 0", f.name());
            assert!(parse(&src).is_ok(), "{src}");
        }
    }

    #[test]
    fn errors() {
        assert!(parse("met = 1").is_err()); // single '='
        assert!(parse("met >").is_err());
        assert!(parse("bogus > 1").is_err());
        assert!(parse("min(1) > 0").unwrap().check().is_err()); // arity at check
        assert!(parse("met > 1 extra").is_err());
        assert!(parse("@").is_err());
    }
}
