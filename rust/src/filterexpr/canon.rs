//! Filter-expression canonicalizer — the front half of `qcache` query
//! fingerprinting. Two users submitting the *same selection written
//! differently* ("met > 30 && n_tracks >= 2" vs "n_tracks >= 2 &&
//! met>30") must map to one cache key, so [`canonicalize`] rewrites a
//! **typechecked** AST into a normal form and [`encode`] serialises that
//! form into the stable byte string the fingerprint hashes.
//!
//! The rewrites are strictly semantics-preserving — cached results are
//! served in place of recomputation, so a canonical form that accepted
//! a different event set would silently corrupt physics. Every rule
//! below is justified against both evaluators (the tree walk and the
//! column bytecode, which are themselves bit-identical):
//!
//! - **Constant folding** of all-literal subtrees, using the *same* f64
//!   operations as evaluation (`+ - * /`, comparisons, `abs`,
//!   `max(0,·).sqrt()`, `min`/`max`), so a folded constant is the value
//!   evaluation would have produced.
//! - **Commutative operand ordering** for `&&`, `||`, `+`, `*`, `==`,
//!   `!=`: IEEE-754 addition and multiplication are commutative
//!   (including signed zeros; differing NaN *payloads* cannot leak into
//!   an accept set because every comparison on NaN is `false`), and the
//!   logical/equality operators are symmetric over total, effect-free
//!   operands. `&&`/`||` chains are additionally flattened, deduplicated
//!   and sorted (boolean AND/OR is associative and idempotent; operands
//!   are total, so dropping a duplicate or reordering cannot change the
//!   outcome). `min`/`max`, `-`, `/` and the inequalities are **not**
//!   reordered: e.g. `1 / min(0.0, -0.0)` genuinely depends on which
//!   zero wins.
//! - **Comparison direction**: `a > b` ⇒ `b < a`, `a >= b` ⇒ `b <= a`
//!   (same f64 comparison, operands are effect-free). `!(a < b)` is NOT
//!   rewritten to `a >= b` — those differ on NaN.
//! - **Double-negation elimination**: `!!x` ⇒ `x` and `-(-x)` ⇒ `x`
//!   (f64 negation is an exact sign-bit flip). Logical identities
//!   `true && x` ⇒ `x`, `false || x` ⇒ `x` and the absorbing duals also
//!   apply — safe because operands are total (a division by zero yields
//!   ±inf/NaN, never a trap).
//!
//! [`pretty`] renders an AST back to parseable source (used by tests to
//! assert fingerprint stability across a pretty-print → re-parse round
//! trip, and by humans inspecting cache keys). Non-finite literals
//! print as overflow/0-over-0 forms that re-parse to the same *value*
//! (NaN payloads are not preserved by `pretty`; [`encode`] preserves
//! exact bits).

use crate::events::{FeatureId, NUM_FEATURES};
use crate::filterexpr::ast::{BinOp, Expr, Func, UnOp};

/// Rewrite a **typechecked** expression into canonical form. The result
/// accepts bit-identically to the input on every feature vector (see
/// the module docs for the rule-by-rule argument and
/// `tests/proptests.rs` for the randomized oracle check).
pub fn canonicalize(expr: &Expr) -> Expr {
    match expr {
        Expr::Num(_) | Expr::Bool(_) | Expr::Feature(_) => expr.clone(),
        Expr::Un(op, a) => {
            let a = canonicalize(a);
            match (*op, a) {
                (UnOp::Not, Expr::Un(UnOp::Not, inner)) => *inner,
                (UnOp::Neg, Expr::Un(UnOp::Neg, inner)) => *inner,
                (UnOp::Not, Expr::Bool(b)) => Expr::Bool(!b),
                (UnOp::Neg, Expr::Num(n)) => Expr::Num(-n),
                (op, a) => Expr::Un(op, Box::new(a)),
            }
        }
        Expr::Bin(op, a, b) => {
            canon_bin(*op, canonicalize(a), canonicalize(b))
        }
        Expr::Call(f, args) => {
            let args: Vec<Expr> =
                args.iter().map(canonicalize).collect();
            if let Some(ns) = all_nums(&args) {
                return Expr::Num(match f {
                    Func::Abs => ns[0].abs(),
                    Func::Sqrt => ns[0].max(0.0).sqrt(),
                    Func::Min => ns[0].min(ns[1]),
                    Func::Max => ns[0].max(ns[1]),
                });
            }
            Expr::Call(*f, args)
        }
    }
}

fn all_nums(args: &[Expr]) -> Option<Vec<f64>> {
    args.iter()
        .map(|a| match a {
            Expr::Num(n) => Some(*n),
            _ => None,
        })
        .collect()
}

fn canon_bin(op: BinOp, a: Expr, b: Expr) -> Expr {
    // constant folding with evaluation's own f64 semantics
    if let (Expr::Num(x), Expr::Num(y)) = (&a, &b) {
        let (x, y) = (*x, *y);
        match op {
            BinOp::Add => return Expr::Num(x + y),
            BinOp::Sub => return Expr::Num(x - y),
            BinOp::Mul => return Expr::Num(x * y),
            BinOp::Div => return Expr::Num(x / y),
            BinOp::Lt => return Expr::Bool(x < y),
            BinOp::Le => return Expr::Bool(x <= y),
            BinOp::Gt => return Expr::Bool(x > y),
            BinOp::Ge => return Expr::Bool(x >= y),
            BinOp::Eq => return Expr::Bool(x == y),
            BinOp::Ne => return Expr::Bool(x != y),
            BinOp::And | BinOp::Or => {}
        }
    }
    match op {
        BinOp::And | BinOp::Or => canon_logical(op, a, b),
        // normalise comparison direction to < / <=
        BinOp::Gt => Expr::Bin(BinOp::Lt, Box::new(b), Box::new(a)),
        BinOp::Ge => Expr::Bin(BinOp::Le, Box::new(b), Box::new(a)),
        BinOp::Add | BinOp::Mul | BinOp::Eq | BinOp::Ne => {
            let (a, b) = if encode(&b) < encode(&a) { (b, a) } else { (a, b) };
            Expr::Bin(op, Box::new(a), Box::new(b))
        }
        _ => Expr::Bin(op, Box::new(a), Box::new(b)),
    }
}

/// Flatten an `&&`/`||` chain, apply identity/absorbing constants,
/// dedupe, sort by encoding, rebuild left-associated.
fn canon_logical(op: BinOp, a: Expr, b: Expr) -> Expr {
    // `absorb`: the constant that decides the whole chain
    // (`false` for &&, `true` for ||); its negation is the identity.
    let absorb = op == BinOp::Or;
    let mut terms = Vec::new();
    flatten(op, a, &mut terms);
    flatten(op, b, &mut terms);
    let mut kept: Vec<(Vec<u8>, Expr)> = Vec::new();
    for t in terms {
        match t {
            Expr::Bool(c) if c == absorb => return Expr::Bool(absorb),
            Expr::Bool(_) => {} // identity element: drop
            other => kept.push((encode(&other), other)),
        }
    }
    if kept.is_empty() {
        return Expr::Bool(!absorb);
    }
    kept.sort_by(|(ka, _), (kb, _)| ka.cmp(kb));
    kept.dedup_by(|(ka, _), (kb, _)| ka == kb);
    let mut it = kept.into_iter().map(|(_, e)| e);
    let first = it.next().expect("non-empty");
    it.fold(first, |acc, t| {
        Expr::Bin(op, Box::new(acc), Box::new(t))
    })
}

fn flatten(op: BinOp, e: Expr, out: &mut Vec<Expr>) {
    match e {
        Expr::Bin(o, a, b) if o == op => {
            flatten(op, *a, out);
            flatten(op, *b, out);
        }
        other => out.push(other),
    }
}

// --- stable byte encoding -----------------------------------------------

const TAG_NUM: u8 = 1;
const TAG_BOOL: u8 = 2;
const TAG_FEAT: u8 = 3;
const TAG_UN: u8 = 4;
const TAG_BIN: u8 = 5;
const TAG_CALL: u8 = 6;

fn binop_code(op: BinOp) -> u8 {
    match op {
        BinOp::Or => 0,
        BinOp::And => 1,
        BinOp::Lt => 2,
        BinOp::Le => 3,
        BinOp::Gt => 4,
        BinOp::Ge => 5,
        BinOp::Eq => 6,
        BinOp::Ne => 7,
        BinOp::Add => 8,
        BinOp::Sub => 9,
        BinOp::Mul => 10,
        BinOp::Div => 11,
    }
}

fn unop_code(op: UnOp) -> u8 {
    match op {
        UnOp::Not => 0,
        UnOp::Neg => 1,
    }
}

fn func_code(f: Func) -> u8 {
    match f {
        Func::Abs => 0,
        Func::Min => 1,
        Func::Max => 2,
        Func::Sqrt => 3,
    }
}

/// Serialise an expression into a stable, platform-independent byte
/// string: equal bytes ⇔ structurally equal trees (f64 literals compare
/// by bit pattern, so `-0.0` and `0.0` — genuinely different values
/// under division — stay distinct). Canonicalize first if you want
/// semantically-equal-modulo-rewrites expressions to collide.
pub fn encode(expr: &Expr) -> Vec<u8> {
    let mut out = Vec::new();
    encode_into(expr, &mut out);
    out
}

fn encode_into(e: &Expr, out: &mut Vec<u8>) {
    match e {
        Expr::Num(n) => {
            out.push(TAG_NUM);
            out.extend_from_slice(&n.to_bits().to_le_bytes());
        }
        Expr::Bool(b) => {
            out.push(TAG_BOOL);
            out.push(*b as u8);
        }
        Expr::Feature(f) => {
            out.push(TAG_FEAT);
            out.extend_from_slice(&f.to_le_bytes());
        }
        Expr::Un(op, a) => {
            out.push(TAG_UN);
            out.push(unop_code(*op));
            encode_into(a, out);
        }
        Expr::Bin(op, a, b) => {
            out.push(TAG_BIN);
            out.push(binop_code(*op));
            encode_into(a, out);
            encode_into(b, out);
        }
        Expr::Call(f, args) => {
            out.push(TAG_CALL);
            out.push(func_code(*f));
            out.push(args.len() as u8);
            for a in args {
                encode_into(a, out);
            }
        }
    }
}

// --- pretty printing ----------------------------------------------------

fn binop_src(op: BinOp) -> &'static str {
    match op {
        BinOp::Or => "||",
        BinOp::And => "&&",
        BinOp::Lt => "<",
        BinOp::Le => "<=",
        BinOp::Gt => ">",
        BinOp::Ge => ">=",
        BinOp::Eq => "==",
        BinOp::Ne => "!=",
        BinOp::Add => "+",
        BinOp::Sub => "-",
        BinOp::Mul => "*",
        BinOp::Div => "/",
    }
}

fn func_src(f: Func) -> &'static str {
    match f {
        Func::Abs => "abs",
        Func::Sqrt => "sqrt",
        Func::Min => "min",
        Func::Max => "max",
    }
}

/// Render an expression as parseable filter source (fully
/// parenthesised). Finite numbers round-trip exactly (Rust's shortest
/// f64 formatting); `±inf` prints as an overflowing literal (`1e999`)
/// and NaN as `(0/0)`, both of which re-parse (and, for NaN,
/// re-canonicalize) to the same *value* though not necessarily the same
/// NaN payload bits. Feature indices must be in range (true for any
/// compiled filter).
pub fn pretty(expr: &Expr) -> String {
    match expr {
        Expr::Num(n) => {
            if n.is_nan() {
                "(0/0)".to_string()
            } else if n.is_infinite() {
                if *n > 0.0 {
                    "1e999".to_string()
                } else {
                    "(-1e999)".to_string()
                }
            } else if *n < 0.0 || (*n == 0.0 && n.is_sign_negative()) {
                format!("(-{})", -n)
            } else {
                format!("{n}")
            }
        }
        Expr::Bool(b) => b.to_string(),
        Expr::Feature(f) => {
            debug_assert!((*f as usize) < NUM_FEATURES);
            FeatureId::ALL
                .get(*f as usize)
                .map(|id| id.name().to_string())
                .unwrap_or_else(|| format!("feature_{f}"))
        }
        Expr::Un(UnOp::Not, a) => format!("(!{})", pretty(a)),
        Expr::Un(UnOp::Neg, a) => format!("(-{})", pretty(a)),
        Expr::Bin(op, a, b) => {
            format!("({} {} {})", pretty(a), binop_src(*op), pretty(b))
        }
        Expr::Call(f, args) => {
            let inner: Vec<String> = args.iter().map(pretty).collect();
            format!("{}({})", func_src(*f), inner.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filterexpr::parser::parse;
    use crate::filterexpr::CompiledFilter;

    fn canon_src(src: &str) -> Expr {
        canonicalize(&parse(src).unwrap())
    }

    #[test]
    fn commuted_conjunctions_collide() {
        let a = canon_src("met > 30 && n_tracks >= 2");
        let b = canon_src("n_tracks>=2&&met   >30");
        assert_eq!(encode(&a), encode(&b));
    }

    #[test]
    fn flattened_chains_collide_in_any_order() {
        let a = canon_src("met > 1 && sum_pt > 2 && max_pt > 3");
        let b = canon_src("max_pt > 3 && (met > 1 && sum_pt > 2)");
        let c = canon_src("sum_pt > 2 && max_pt > 3 && met > 1");
        assert_eq!(encode(&a), encode(&b));
        assert_eq!(encode(&a), encode(&c));
    }

    #[test]
    fn comparison_direction_normalises() {
        let a = canon_src("met > 30");
        let b = canon_src("30 < met");
        assert_eq!(encode(&a), encode(&b));
        let c = canon_src("met >= 30");
        let d = canon_src("30 <= met");
        assert_eq!(encode(&c), encode(&d));
    }

    #[test]
    fn constants_fold_with_eval_semantics() {
        assert_eq!(canon_src("met > 10 + 20"), canon_src("met > 30"));
        assert_eq!(
            canon_src("met > 2 * 3 + 1 && true"),
            canon_src("met > 7")
        );
        // absorbing / identity constants
        assert_eq!(canon_src("false && met > 1"), Expr::Bool(false));
        assert_eq!(canon_src("true || met > 1"), Expr::Bool(true));
        assert_eq!(canon_src("true && met > 1"), canon_src("met > 1"));
        assert_eq!(canon_src("false || met > 1"), canon_src("met > 1"));
        // all-constant calls fold too
        assert_eq!(canon_src("met > min(3, 2)"), canon_src("met > 2"));
        assert_eq!(canon_src("met > abs(-4)"), canon_src("met > 4"));
    }

    #[test]
    fn double_negation_eliminated() {
        assert_eq!(canon_src("!!(met > 1)"), canon_src("met > 1"));
        assert_eq!(canon_src("--met < 1"), canon_src("met < 1"));
        // single negation survives
        assert_eq!(
            canon_src("!(met > 1)"),
            Expr::Un(
                UnOp::Not,
                Box::new(canon_src("met > 1")),
            )
        );
    }

    #[test]
    fn duplicate_terms_dedupe() {
        let a = canon_src("met > 1 && met > 1");
        assert_eq!(encode(&a), encode(&canon_src("met > 1")));
        let b = canon_src("met > 1 || met > 1 || sum_pt > 2");
        assert_eq!(encode(&b), encode(&canon_src("sum_pt > 2 || met > 1")));
    }

    #[test]
    fn distinct_selections_stay_distinct() {
        let pairs = [
            ("met > 30", "met >= 30"),
            ("met > 30", "met > 31"),
            ("met > 30", "sum_pt > 30"),
            ("met > 1 && sum_pt > 2", "met > 1 || sum_pt > 2"),
            ("!(met < 1)", "met >= 1"), // differ on NaN: must NOT collide
        ];
        for (a, b) in pairs {
            assert_ne!(
                encode(&canon_src(a)),
                encode(&canon_src(b)),
                "{a} vs {b}"
            );
        }
    }

    #[test]
    fn min_max_sub_div_are_not_reordered() {
        // 1 / min(0, -0) depends on which zero wins: operand order is
        // load-bearing and the canonicalizer must leave it alone
        let a = parse("1 / min(met, sum_pt) > 0").unwrap();
        let b = parse("1 / min(sum_pt, met) > 0").unwrap();
        assert_ne!(encode(&canonicalize(&a)), encode(&canonicalize(&b)));
        assert_ne!(
            encode(&canon_src("met - sum_pt > 0")),
            encode(&canon_src("sum_pt - met > 0")),
        );
    }

    #[test]
    fn canonicalization_is_idempotent() {
        for src in [
            "max_pair_mass > 80 && max_pair_mass < 100 && max_pt > 20",
            "n_tracks >= 4 || (met > 30 && ht_frac < 0.8)",
            "abs(max_abs_eta - 2.5) < min(1.0, ht_frac)",
            "!(met > 10) || sqrt(sum_pt) >= 3",
            "2 + 3 * 4 > 13 && met >= 0",
            "true && (false || met > 1)",
        ] {
            let once = canon_src(src);
            let twice = canonicalize(&once);
            assert_eq!(encode(&once), encode(&twice), "{src}");
        }
    }

    #[test]
    fn pretty_reparses_to_the_same_canonical_form() {
        for src in [
            "max_pair_mass > 80 && max_pair_mass < 100 && max_pt > 20",
            "n_tracks >= 4 || (met > 30 && ht_frac < 0.8)",
            "abs(max_abs_eta - 2.5) < min(1.0, ht_frac)",
            "!(met > 10) || sqrt(sum_pt) >= 3",
            "-met < -1.5",
            "sum_pt > 1.5e2",
        ] {
            let canon = canon_src(src);
            let reparsed = parse(&pretty(&canon))
                .unwrap_or_else(|e| panic!("pretty({src}) unparseable: {e}"));
            assert_eq!(
                encode(&canon),
                encode(&canonicalize(&reparsed)),
                "{src} -> {}",
                pretty(&canon)
            );
        }
    }

    #[test]
    fn canonical_form_still_compiles_and_accepts_identically() {
        let src = "max_pair_mass > 80 && max_pair_mass < 100 || met > 50";
        let orig = parse(src).unwrap();
        let canon = canonicalize(&orig);
        let f0 = CompiledFilter::new(orig).unwrap();
        let f1 = CompiledFilter::new(canon).unwrap();
        let mut feats = [0f32; NUM_FEATURES];
        for (mass, met) in
            [(91.0, 0.0), (120.0, 0.0), (91.0, 60.0), (0.0, 60.0), (0.0, 0.0)]
        {
            feats[FeatureId::MaxPairMass as usize] = mass;
            feats[FeatureId::Met as usize] = met;
            assert_eq!(f0.accept(&feats), f1.accept(&feats));
        }
    }
}
