//! User filter expressions — the "filter expression" field of the
//! paper's submit form (§5, Fig 4). Expressions are evaluated in rust
//! against the per-event feature vector the L1 kernel produced, so the
//! AOT HLO stays static while users write arbitrary cuts:
//!
//! ```text
//! max_pair_mass > 80 && max_pair_mass < 100 && max_pt > 20
//! n_tracks >= 4 || (met > 30 && ht_frac < 0.8)
//! abs(max_abs_eta - 2.5) < 1.0
//! ```
//!
//! Grammar (precedence low→high): `||`, `&&`, comparisons, `+ -`, `* /`,
//! unary `! -`, primary (number, feature name, `true/false`,
//! parentheses, `abs/min/max` calls). A type checker rejects nonsense
//! like `met && 3` before any event is touched, and compilation rejects
//! feature indices outside the kernel's `NUM_FEATURES`-wide rows.
//!
//! Execution is vectorized: compilation flattens the AST into a postfix
//! [`bytecode`] program whose opcodes each run one tight loop over
//! fixed-width chunks of their operand columns (explicit `std::simd`
//! under `--features simd`, an autovectorizable chunked build on stable
//! — see [`lanes`]), with comparisons producing **bitmask** words so
//! boolean combinators process 64 rows per instruction. Buffers are
//! recycled across pages via [`VmScratch`]. Two reference evaluators
//! are retained and tested bit-identical against the SIMD path: the
//! PR-3 scalar column VM and the recursive tree walk.
//!
//! For query-result caching ([`crate::qcache`]), [`canon`] rewrites a
//! typechecked AST into a canonical form (constant folding, commutative
//! operand ordering, double-negation elimination — all strictly
//! semantics-preserving) and serialises it into the stable byte string
//! that query fingerprints hash.

pub mod ast;
pub mod bytecode;
pub mod canon;
pub mod eval;
pub mod lanes;
pub mod parser;

pub use ast::{BinOp, Expr, Ty, UnOp};
pub use bytecode::{Op, Program, VmScratch};
pub use canon::{canonicalize, encode as encode_canonical, pretty};
pub use eval::{CompiledFilter, EvalError};
pub use parser::{parse, ParseError};

/// Convenience: parse + typecheck + compile in one step.
pub fn compile(src: &str) -> Result<CompiledFilter, String> {
    let expr = parse(src).map_err(|e| e.to_string())?;
    CompiledFilter::new(expr).map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::NUM_FEATURES;

    fn feats(vals: &[(usize, f32)]) -> [f32; NUM_FEATURES] {
        let mut f = [0f32; NUM_FEATURES];
        for (i, v) in vals {
            f[*i] = *v;
        }
        f
    }

    #[test]
    fn end_to_end_physics_cut() {
        let f = compile(
            "max_pair_mass > 80 && max_pair_mass < 100 && max_pt > 20",
        )
        .unwrap();
        // feature 5 = max_pair_mass, 2 = max_pt
        assert!(f.accept(&feats(&[(5, 91.0), (2, 45.0)])));
        assert!(!f.accept(&feats(&[(5, 91.0), (2, 10.0)])));
        assert!(!f.accept(&feats(&[(5, 120.0), (2, 45.0)])));
    }

    #[test]
    fn type_errors_rejected() {
        assert!(compile("met && 3").is_err());
        assert!(compile("true + 1").is_err());
        assert!(compile("unknown_feature > 1").is_err());
    }

    #[test]
    fn syntax_errors_rejected() {
        assert!(compile("met >").is_err());
        assert!(compile("(met > 1").is_err());
        assert!(compile("").is_err());
    }
}
