//! Filter-expression AST and type checking.
//!
//! `Expr::Feature` carries a raw feature *index* (what the parser
//! resolves feature names to via `events::FeatureId`). The index is NOT
//! validated here — programmatic AST construction can name any index —
//! so `CompiledFilter::new` bounds-checks every referenced feature
//! against `NUM_FEATURES` before an expression may touch event data
//! (see [`Expr::max_feature`]).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Or,
    And,
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
    Add,
    Sub,
    Mul,
    Div,
}

impl BinOp {
    pub fn is_comparison(self) -> bool {
        matches!(
            self,
            BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge | BinOp::Eq | BinOp::Ne
        )
    }

    pub fn is_logical(self) -> bool {
        matches!(self, BinOp::Or | BinOp::And)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Not,
    Neg,
}

/// Built-in functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Func {
    Abs,
    Min,
    Max,
    Sqrt,
}

impl Func {
    pub fn by_name(s: &str) -> Option<Func> {
        Some(match s {
            "abs" => Func::Abs,
            "min" => Func::Min,
            "max" => Func::Max,
            "sqrt" => Func::Sqrt,
            _ => return None,
        })
    }

    pub fn arity(self) -> usize {
        match self {
            Func::Abs | Func::Sqrt => 1,
            Func::Min | Func::Max => 2,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Num(f64),
    Bool(bool),
    /// Index into the per-event feature vector (see `events::FeatureId`
    /// for the named indices the parser produces).
    Feature(u16),
    Un(UnOp, Box<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
    Call(Func, Vec<Expr>),
}

/// Expression types.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ty {
    Num,
    Bool,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TypeError(pub String);

impl std::fmt::Display for TypeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "type error: {}", self.0)
    }
}
impl std::error::Error for TypeError {}

impl Expr {
    /// Highest feature index referenced anywhere in the expression, or
    /// `None` if it touches no features. `CompiledFilter::new` rejects
    /// expressions whose maximum is >= `NUM_FEATURES` — indexing past
    /// the feature vector must be a compile error, never a runtime
    /// panic in the node hot loop.
    pub fn max_feature(&self) -> Option<u16> {
        match self {
            Expr::Num(_) | Expr::Bool(_) => None,
            Expr::Feature(f) => Some(*f),
            Expr::Un(_, e) => e.max_feature(),
            Expr::Bin(_, a, b) => a.max_feature().max(b.max_feature()),
            Expr::Call(_, args) => {
                args.iter().filter_map(|a| a.max_feature()).max()
            }
        }
    }

    /// Infer & check the type of the expression.
    pub fn check(&self) -> Result<Ty, TypeError> {
        match self {
            Expr::Num(_) => Ok(Ty::Num),
            Expr::Bool(_) => Ok(Ty::Bool),
            Expr::Feature(_) => Ok(Ty::Num),
            Expr::Un(UnOp::Not, e) => {
                if e.check()? == Ty::Bool {
                    Ok(Ty::Bool)
                } else {
                    Err(TypeError("'!' needs a boolean".into()))
                }
            }
            Expr::Un(UnOp::Neg, e) => {
                if e.check()? == Ty::Num {
                    Ok(Ty::Num)
                } else {
                    Err(TypeError("'-' needs a number".into()))
                }
            }
            Expr::Bin(op, a, b) => {
                let (ta, tb) = (a.check()?, b.check()?);
                if op.is_logical() {
                    if ta == Ty::Bool && tb == Ty::Bool {
                        Ok(Ty::Bool)
                    } else {
                        Err(TypeError(format!(
                            "logical {op:?} needs booleans"
                        )))
                    }
                } else if op.is_comparison() {
                    if ta == Ty::Num && tb == Ty::Num {
                        Ok(Ty::Bool)
                    } else {
                        Err(TypeError(format!(
                            "comparison {op:?} needs numbers"
                        )))
                    }
                } else if ta == Ty::Num && tb == Ty::Num {
                    Ok(Ty::Num)
                } else {
                    Err(TypeError(format!("arithmetic {op:?} needs numbers")))
                }
            }
            Expr::Call(f, args) => {
                if args.len() != f.arity() {
                    return Err(TypeError(format!(
                        "{f:?} takes {} args, got {}",
                        f.arity(),
                        args.len()
                    )));
                }
                for a in args {
                    if a.check()? != Ty::Num {
                        return Err(TypeError(format!(
                            "{f:?} needs numeric args"
                        )));
                    }
                }
                Ok(Ty::Num)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::FeatureId;

    const MET: u16 = FeatureId::Met as u16;

    #[test]
    fn literal_types() {
        assert_eq!(Expr::Num(1.0).check().unwrap(), Ty::Num);
        assert_eq!(Expr::Bool(true).check().unwrap(), Ty::Bool);
        assert_eq!(Expr::Feature(MET).check().unwrap(), Ty::Num);
    }

    #[test]
    fn comparison_yields_bool() {
        let e = Expr::Bin(
            BinOp::Gt,
            Box::new(Expr::Feature(MET)),
            Box::new(Expr::Num(30.0)),
        );
        assert_eq!(e.check().unwrap(), Ty::Bool);
    }

    #[test]
    fn bad_logical_operand() {
        let e = Expr::Bin(
            BinOp::And,
            Box::new(Expr::Feature(MET)),
            Box::new(Expr::Bool(true)),
        );
        assert!(e.check().is_err());
    }

    #[test]
    fn max_feature_scans_the_whole_tree() {
        assert_eq!(Expr::Num(1.0).max_feature(), None);
        let e = Expr::Bin(
            BinOp::Add,
            Box::new(Expr::Feature(2)),
            Box::new(Expr::Call(
                Func::Max,
                vec![Expr::Feature(7), Expr::Feature(5)],
            )),
        );
        assert_eq!(e.max_feature(), Some(7));
        let deep = Expr::Un(UnOp::Neg, Box::new(Expr::Feature(200)));
        assert_eq!(deep.max_feature(), Some(200));
    }

    #[test]
    fn func_arity_checked() {
        let e = Expr::Call(Func::Min, vec![Expr::Num(1.0)]);
        assert!(e.check().is_err());
        let ok = Expr::Call(Func::Min, vec![Expr::Num(1.0), Expr::Num(2.0)]);
        assert_eq!(ok.check().unwrap(), Ty::Num);
    }
}
