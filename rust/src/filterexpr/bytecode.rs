//! Flat postfix bytecode + vectorized evaluation — the SIMD filter VM
//! on the node hot path.
//!
//! [`compile`] flattens a type-checked [`Expr`] into postfix [`Op`]s.
//! Evaluation comes in three tiers, all required to produce
//! **bit-identical accept sets**:
//!
//! 1. [`Program::eval_bits_into`] — the production path. Every opcode
//!    runs one tight loop over fixed-width chunks of its operand
//!    columns (explicit `std::simd` under `--features simd`, an
//!    autovectorizable chunked scalar build on stable — see
//!    [`lanes`]), and comparisons emit **bitmask words** (`u64`, one
//!    bit per row) instead of `Vec<bool>`, so `&& || !` above them
//!    collapse to word ops at 64 rows per instruction. Buffers are
//!    recycled through [`VmScratch`] pools: a steady-state page
//!    evaluates with zero allocations.
//! 2. [`Program::eval_into_scalar`] — the PR-3 scalar column VM
//!    (column-at-a-time loops, `Vec<bool>` booleans), retained
//!    verbatim as the differential reference for the SIMD path.
//! 3. The recursive tree walk (`CompiledFilter::accept`) — the
//!    original per-event oracle both VMs are tested against.
//!
//! Deliberate semantics choices keep all three bit-identical:
//!
//! - Arithmetic runs in `f64`, exactly like the tree walk (constants
//!   are `f64` literals; features are widened `f32 → f64`), lane-wise
//!   with no reassociation, FMA contraction, or fast-math.
//! - `min`/`max`/`sqrt` always execute the exact scalar std calls per
//!   lane, even under the `simd` feature: a SIMD min/max intrinsic may
//!   resolve `min(-0.0, +0.0)` to the other zero than the scalar op,
//!   and that sign flips `1 / min(a, b)` between infinities (see
//!   [`lanes`] for the full argument).
//! - `&&` / `||` are evaluated eagerly instead of short-circuited.
//!   That is safe because operands are effect-free and every
//!   comparison yields a plain `bool` even for NaN/∞ inputs (e.g. a
//!   division the tree walk would have skipped), so the boolean
//!   AND/OR of both sides equals the short-circuit result. Constant
//!   operands still fold: `false && …` collapses without touching the
//!   column.
//!
//! [`lanes`]: crate::filterexpr::lanes

use crate::events::NUM_FEATURES;
use crate::filterexpr::ast::{BinOp, Expr, Func, UnOp};
use crate::filterexpr::lanes::{self, ArithOp, CmpOp};

/// One postfix opcode. Operand types are fixed per opcode (the AST is
/// type-checked before compilation), so numeric and boolean slots can
/// live on separate stacks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    /// Push a numeric constant.
    PushNum(f64),
    /// Push a boolean constant.
    PushBool(bool),
    /// Push feature column `f` of the feature matrix (gathered directly
    /// into the working slot — emitted when the program references the
    /// feature exactly once).
    PushFeat(u16),
    /// Push feature column `f` via the per-call gather cache — emitted
    /// when the program references the feature more than once, so the
    /// strided gather happens once and later uses are contiguous copies.
    PushFeatCached(u16),
    // numeric → numeric
    Neg,
    Add,
    Sub,
    Mul,
    Div,
    Abs,
    Sqrt,
    Min,
    Max,
    // numeric × numeric → boolean
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
    // boolean → boolean
    Not,
    And,
    Or,
}

/// A compiled filter program: postfix opcodes over a two-typed column
/// stack.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    ops: Vec<Op>,
}

/// Flatten a type-checked expression into postfix bytecode. The caller
/// (`CompiledFilter::new`) guarantees the expression type-checks and
/// references only in-bounds features. Features referenced more than
/// once are rewritten to [`Op::PushFeatCached`] so each column is
/// gathered from the strided matrix only once per page.
pub fn compile(expr: &Expr) -> Program {
    let mut ops = Vec::new();
    emit(expr, &mut ops);
    // common-subexpression pass over feature loads
    let max_feat = ops
        .iter()
        .filter_map(|op| match op {
            Op::PushFeat(f) => Some(*f as usize),
            _ => None,
        })
        .max();
    if let Some(max_feat) = max_feat {
        let mut uses = vec![0u32; max_feat + 1];
        for op in &ops {
            if let Op::PushFeat(f) = op {
                uses[*f as usize] += 1;
            }
        }
        for op in ops.iter_mut() {
            if let Op::PushFeat(f) = *op {
                if uses[f as usize] > 1 {
                    *op = Op::PushFeatCached(f);
                }
            }
        }
    }
    Program { ops }
}

fn emit(e: &Expr, out: &mut Vec<Op>) {
    match e {
        Expr::Num(n) => out.push(Op::PushNum(*n)),
        Expr::Bool(b) => out.push(Op::PushBool(*b)),
        Expr::Feature(f) => out.push(Op::PushFeat(*f)),
        Expr::Un(op, a) => {
            emit(a, out);
            out.push(match op {
                UnOp::Neg => Op::Neg,
                UnOp::Not => Op::Not,
            });
        }
        Expr::Bin(op, a, b) => {
            emit(a, out);
            emit(b, out);
            out.push(match op {
                BinOp::Add => Op::Add,
                BinOp::Sub => Op::Sub,
                BinOp::Mul => Op::Mul,
                BinOp::Div => Op::Div,
                BinOp::Lt => Op::Lt,
                BinOp::Le => Op::Le,
                BinOp::Gt => Op::Gt,
                BinOp::Ge => Op::Ge,
                BinOp::Eq => Op::Eq,
                BinOp::Ne => Op::Ne,
                BinOp::And => Op::And,
                BinOp::Or => Op::Or,
            });
        }
        Expr::Call(f, args) => {
            for a in args {
                emit(a, out);
            }
            out.push(match f {
                Func::Abs => Op::Abs,
                Func::Sqrt => Op::Sqrt,
                Func::Min => Op::Min,
                Func::Max => Op::Max,
            });
        }
    }
}

/// A numeric stack slot: either a broadcast constant or a whole column.
enum NumSlot {
    Const(f64),
    Col(Vec<f64>),
}

/// A boolean stack slot of the scalar reference VM.
enum BoolSlot {
    Const(bool),
    Col(Vec<bool>),
}

/// A boolean stack slot of the vectorized VM: a broadcast constant or a
/// bitmask (bit `i` of word `w` = row `64*w + i`). Intermediate masks
/// may carry garbage in the bits past the row count (a `Not` flips
/// them); the final mask is trimmed before it leaves the VM.
enum MaskSlot {
    Const(bool),
    Bits(Vec<u64>),
}

/// Reusable evaluation state: the typed value stacks plus buffer pools.
/// Keep one per worker pipeline and feed it every page — after the
/// first page no evaluation allocates.
#[derive(Default)]
pub struct VmScratch {
    nums: Vec<NumSlot>,
    bools: Vec<BoolSlot>,
    masks: Vec<MaskSlot>,
    num_pool: Vec<Vec<f64>>,
    bool_pool: Vec<Vec<bool>>,
    mask_pool: Vec<Vec<u64>>,
    /// per-`eval` gather cache for `Op::PushFeatCached`, indexed by
    /// feature id; entries are invalidated (returned to the pool) at the
    /// start of every evaluation
    feat_cache: Vec<Option<Vec<f64>>>,
}

impl VmScratch {
    pub fn new() -> Self {
        VmScratch::default()
    }

    fn take_num(&mut self) -> Vec<f64> {
        let mut v = self.num_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    fn take_bool(&mut self) -> Vec<bool> {
        let mut v = self.bool_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    fn take_mask(&mut self) -> Vec<u64> {
        let mut v = self.mask_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    fn retire_num(&mut self, v: Vec<f64>) {
        self.num_pool.push(v);
    }

    fn retire_bool(&mut self, v: Vec<bool>) {
        self.bool_pool.push(v);
    }

    fn retire_mask(&mut self, v: Vec<u64>) {
        self.mask_pool.push(v);
    }

    fn pop_num(&mut self) -> NumSlot {
        self.nums.pop().expect("typechecked: numeric operand")
    }

    fn pop_bool(&mut self) -> BoolSlot {
        self.bools.pop().expect("typechecked: boolean operand")
    }

    fn pop_mask(&mut self) -> MaskSlot {
        self.masks.pop().expect("typechecked: boolean operand")
    }

    /// Invalidate the gather cache and gather feature `f` into a fresh
    /// working column (contiguous copy when cached).
    fn push_feat(&mut self, feats: &[f32], n: usize, f: usize, cached: bool) {
        if cached {
            if self.feat_cache.len() <= f {
                self.feat_cache.resize_with(f + 1, || None);
            }
            if self.feat_cache[f].is_none() {
                let mut col = self.take_num();
                gather(feats, n, f, &mut col);
                self.feat_cache[f] = Some(col);
            }
            let mut col = self.take_num();
            col.extend_from_slice(
                self.feat_cache[f].as_deref().expect("just filled"),
            );
            self.nums.push(NumSlot::Col(col));
        } else {
            let mut col = self.take_num();
            gather(feats, n, f, &mut col);
            self.nums.push(NumSlot::Col(col));
        }
    }

    /// Return last page's gather cache entries to the pool.
    fn reset_feat_cache(&mut self) {
        for slot in self.feat_cache.iter_mut() {
            if let Some(v) = slot.take() {
                self.num_pool.push(v);
            }
        }
    }
}

/// Strided gather of one feature column out of the row-major matrix.
fn gather(feats: &[f32], n: usize, f: usize, col: &mut Vec<f64>) {
    col.reserve(n);
    for i in 0..n {
        col.push(feats[i * NUM_FEATURES + f] as f64);
    }
}

impl Program {
    pub fn ops(&self) -> &[Op] {
        &self.ops
    }

    /// Vectorized evaluation over the first `n` rows of a row-major
    /// `(B, NUM_FEATURES)` feature matrix, writing the accept mask as
    /// bitmask words into `out` (bit `i` of word `w` = row `64*w + i`;
    /// bits at and past row `n` are zero). This is the production path:
    /// chunked/SIMD arithmetic, masked compares, word-wise boolean
    /// algebra. `scratch` carries the reusable buffers.
    pub fn eval_bits_into(
        &self,
        feats: &[f32],
        n: usize,
        scratch: &mut VmScratch,
        out: &mut Vec<u64>,
    ) {
        debug_assert!(n * NUM_FEATURES <= feats.len());
        debug_assert!(scratch.nums.is_empty() && scratch.masks.is_empty());
        scratch.reset_feat_cache();
        for op in &self.ops {
            match *op {
                Op::PushNum(c) => scratch.nums.push(NumSlot::Const(c)),
                Op::PushBool(c) => scratch.masks.push(MaskSlot::Const(c)),
                Op::PushFeat(f) => {
                    scratch.push_feat(feats, n, f as usize, false)
                }
                Op::PushFeatCached(f) => {
                    scratch.push_feat(feats, n, f as usize, true)
                }
                Op::Neg => un_num(scratch, |x| -x),
                Op::Abs => un_num(scratch, f64::abs),
                // identical guard to the tree walk: sqrt of a negative
                // intermediate clamps to 0 instead of NaN
                Op::Sqrt => un_num(scratch, |x| x.max(0.0).sqrt()),
                Op::Add => bin_num_vec(scratch, ArithOp::Add),
                Op::Sub => bin_num_vec(scratch, ArithOp::Sub),
                Op::Mul => bin_num_vec(scratch, ArithOp::Mul),
                Op::Div => bin_num_vec(scratch, ArithOp::Div),
                // scalar std semantics per lane on purpose — see the
                // module docs on min/max signed zeros
                Op::Min => bin_num(scratch, f64::min),
                Op::Max => bin_num(scratch, f64::max),
                Op::Lt => cmp_vec(scratch, CmpOp::Lt),
                Op::Le => cmp_vec(scratch, CmpOp::Le),
                Op::Gt => cmp_vec(scratch, CmpOp::Gt),
                Op::Ge => cmp_vec(scratch, CmpOp::Ge),
                Op::Eq => cmp_vec(scratch, CmpOp::Eq),
                Op::Ne => cmp_vec(scratch, CmpOp::Ne),
                Op::Not => {
                    let r = match scratch.pop_mask() {
                        MaskSlot::Const(c) => MaskSlot::Const(!c),
                        MaskSlot::Bits(mut v) => {
                            for w in v.iter_mut() {
                                *w = !*w;
                            }
                            MaskSlot::Bits(v)
                        }
                    };
                    scratch.masks.push(r);
                }
                Op::And => bin_mask(scratch, true),
                Op::Or => bin_mask(scratch, false),
            }
        }
        out.clear();
        match scratch.pop_mask() {
            MaskSlot::Const(c) => {
                out.resize(lanes::mask_words(n), if c { !0u64 } else { 0 });
            }
            MaskSlot::Bits(v) => {
                out.extend_from_slice(&v);
                scratch.retire_mask(v);
            }
        }
        lanes::trim_mask(out, n);
        debug_assert!(scratch.nums.is_empty() && scratch.masks.is_empty());
    }

    /// Vectorized evaluation with a `Vec<bool>` mask (cleared first) —
    /// a compatibility wrapper over [`eval_bits_into`]; bit-consumers
    /// (the node executor) use the bitmask form directly.
    ///
    /// [`eval_bits_into`]: Program::eval_bits_into
    pub fn eval_into(
        &self,
        feats: &[f32],
        n: usize,
        scratch: &mut VmScratch,
        out: &mut Vec<bool>,
    ) {
        let mut bits = scratch.take_mask();
        self.eval_bits_into(feats, n, scratch, &mut bits);
        out.clear();
        out.reserve(n);
        for i in 0..n {
            out.push(bits[i / 64] >> (i % 64) & 1 == 1);
        }
        scratch.retire_mask(bits);
    }

    /// The PR-3 **scalar column VM**, retained as the differential
    /// reference for the vectorized path (and the bench baseline):
    /// column-at-a-time per-element loops, `Vec<bool>` booleans. Writes
    /// the accept mask for the first `n` rows into `out` (cleared
    /// first). Must stay bit-identical to both [`eval_bits_into`] and
    /// the tree-walk oracle.
    ///
    /// [`eval_bits_into`]: Program::eval_bits_into
    pub fn eval_into_scalar(
        &self,
        feats: &[f32],
        n: usize,
        scratch: &mut VmScratch,
        out: &mut Vec<bool>,
    ) {
        debug_assert!(n * NUM_FEATURES <= feats.len());
        debug_assert!(scratch.nums.is_empty() && scratch.bools.is_empty());
        scratch.reset_feat_cache();
        for op in &self.ops {
            match *op {
                Op::PushNum(c) => scratch.nums.push(NumSlot::Const(c)),
                Op::PushBool(c) => scratch.bools.push(BoolSlot::Const(c)),
                Op::PushFeat(f) => {
                    scratch.push_feat(feats, n, f as usize, false)
                }
                Op::PushFeatCached(f) => {
                    scratch.push_feat(feats, n, f as usize, true)
                }
                Op::Neg => un_num(scratch, |x| -x),
                Op::Abs => un_num(scratch, f64::abs),
                Op::Sqrt => un_num(scratch, |x| x.max(0.0).sqrt()),
                Op::Add => bin_num(scratch, |x, y| x + y),
                Op::Sub => bin_num(scratch, |x, y| x - y),
                Op::Mul => bin_num(scratch, |x, y| x * y),
                Op::Div => bin_num(scratch, |x, y| x / y),
                Op::Min => bin_num(scratch, f64::min),
                Op::Max => bin_num(scratch, f64::max),
                Op::Lt => cmp(scratch, n, |x, y| x < y),
                Op::Le => cmp(scratch, n, |x, y| x <= y),
                Op::Gt => cmp(scratch, n, |x, y| x > y),
                Op::Ge => cmp(scratch, n, |x, y| x >= y),
                Op::Eq => cmp(scratch, n, |x, y| x == y),
                Op::Ne => cmp(scratch, n, |x, y| x != y),
                Op::Not => {
                    let s = scratch.pop_bool();
                    let r = match s {
                        BoolSlot::Const(c) => BoolSlot::Const(!c),
                        BoolSlot::Col(mut v) => {
                            for b in v.iter_mut() {
                                *b = !*b;
                            }
                            BoolSlot::Col(v)
                        }
                    };
                    scratch.bools.push(r);
                }
                Op::And => bin_bool(scratch, true),
                Op::Or => bin_bool(scratch, false),
            }
        }
        out.clear();
        match scratch.pop_bool() {
            BoolSlot::Const(c) => out.resize(n, c),
            BoolSlot::Col(v) => {
                out.extend_from_slice(&v);
                scratch.retire_bool(v);
            }
        }
        debug_assert!(scratch.nums.is_empty() && scratch.bools.is_empty());
    }
}

fn un_num(scratch: &mut VmScratch, f: impl Fn(f64) -> f64) {
    let r = match scratch.pop_num() {
        NumSlot::Const(x) => NumSlot::Const(f(x)),
        NumSlot::Col(mut v) => {
            for x in v.iter_mut() {
                *x = f(*x);
            }
            NumSlot::Col(v)
        }
    };
    scratch.nums.push(r);
}

/// Scalar binary numeric op (the reference VM, and min/max on both
/// paths — elementwise std-call semantics).
fn bin_num(scratch: &mut VmScratch, f: impl Fn(f64, f64) -> f64) {
    let b = scratch.pop_num();
    let a = scratch.pop_num();
    let r = match (a, b) {
        (NumSlot::Const(x), NumSlot::Const(y)) => NumSlot::Const(f(x, y)),
        (NumSlot::Const(x), NumSlot::Col(mut v)) => {
            for y in v.iter_mut() {
                *y = f(x, *y);
            }
            NumSlot::Col(v)
        }
        (NumSlot::Col(mut v), NumSlot::Const(y)) => {
            for x in v.iter_mut() {
                *x = f(*x, y);
            }
            NumSlot::Col(v)
        }
        (NumSlot::Col(mut va), NumSlot::Col(vb)) => {
            for (x, &y) in va.iter_mut().zip(&vb) {
                *x = f(*x, y);
            }
            scratch.retire_num(vb);
            NumSlot::Col(va)
        }
    };
    scratch.nums.push(r);
}

/// Chunked/SIMD binary arithmetic (`+ - * /`) for the vectorized VM.
fn bin_num_vec(scratch: &mut VmScratch, op: ArithOp) {
    let b = scratch.pop_num();
    let a = scratch.pop_num();
    let r = match (a, b) {
        (NumSlot::Const(x), NumSlot::Const(y)) => NumSlot::Const(op.apply(x, y)),
        (NumSlot::Const(x), NumSlot::Col(mut v)) => {
            lanes::arith_const_col(op, x, &mut v);
            NumSlot::Col(v)
        }
        (NumSlot::Col(mut v), NumSlot::Const(y)) => {
            lanes::arith_col_const(op, &mut v, y);
            NumSlot::Col(v)
        }
        (NumSlot::Col(mut va), NumSlot::Col(vb)) => {
            lanes::arith_col_col(op, &mut va, &vb);
            scratch.retire_num(vb);
            NumSlot::Col(va)
        }
    };
    scratch.nums.push(r);
}

/// Masked compare for the vectorized VM: numeric operands in, bitmask
/// out.
fn cmp_vec(scratch: &mut VmScratch, op: CmpOp) {
    let b = scratch.pop_num();
    let a = scratch.pop_num();
    let r = match (a, b) {
        (NumSlot::Const(x), NumSlot::Const(y)) => {
            MaskSlot::Const(op.apply(x, y))
        }
        (NumSlot::Const(x), NumSlot::Col(v)) => {
            let mut out = scratch.take_mask();
            lanes::cmp_const_col(op, x, &v, &mut out);
            scratch.retire_num(v);
            MaskSlot::Bits(out)
        }
        (NumSlot::Col(v), NumSlot::Const(y)) => {
            let mut out = scratch.take_mask();
            lanes::cmp_col_const(op, &v, y, &mut out);
            scratch.retire_num(v);
            MaskSlot::Bits(out)
        }
        (NumSlot::Col(va), NumSlot::Col(vb)) => {
            let mut out = scratch.take_mask();
            lanes::cmp_col_col(op, &va, &vb, &mut out);
            scratch.retire_num(va);
            scratch.retire_num(vb);
            MaskSlot::Bits(out)
        }
    };
    scratch.masks.push(r);
}

/// Word-wise eager boolean AND (`and = true`) or OR (`and = false`)
/// with constant folding — a constant absorbing element drops the other
/// mask. 64 rows per instruction.
fn bin_mask(scratch: &mut VmScratch, and: bool) {
    let b = scratch.pop_mask();
    let a = scratch.pop_mask();
    let r = match (a, b) {
        (MaskSlot::Const(x), MaskSlot::Const(y)) => {
            MaskSlot::Const(if and { x && y } else { x || y })
        }
        (MaskSlot::Const(c), MaskSlot::Bits(v))
        | (MaskSlot::Bits(v), MaskSlot::Const(c)) => {
            if c == and {
                // true && v == v; false || v == v
                MaskSlot::Bits(v)
            } else {
                // false && v == false; true || v == true
                scratch.retire_mask(v);
                MaskSlot::Const(c)
            }
        }
        (MaskSlot::Bits(mut va), MaskSlot::Bits(vb)) => {
            if and {
                for (x, &y) in va.iter_mut().zip(&vb) {
                    *x &= y;
                }
            } else {
                for (x, &y) in va.iter_mut().zip(&vb) {
                    *x |= y;
                }
            }
            scratch.retire_mask(vb);
            MaskSlot::Bits(va)
        }
    };
    scratch.masks.push(r);
}

/// Scalar compare (the reference VM).
fn cmp(scratch: &mut VmScratch, n: usize, f: impl Fn(f64, f64) -> bool) {
    let b = scratch.pop_num();
    let a = scratch.pop_num();
    let r = match (a, b) {
        (NumSlot::Const(x), NumSlot::Const(y)) => BoolSlot::Const(f(x, y)),
        (NumSlot::Const(x), NumSlot::Col(v)) => {
            let mut out = scratch.take_bool();
            out.reserve(n);
            out.extend(v.iter().map(|&y| f(x, y)));
            scratch.retire_num(v);
            BoolSlot::Col(out)
        }
        (NumSlot::Col(v), NumSlot::Const(y)) => {
            let mut out = scratch.take_bool();
            out.reserve(n);
            out.extend(v.iter().map(|&x| f(x, y)));
            scratch.retire_num(v);
            BoolSlot::Col(out)
        }
        (NumSlot::Col(va), NumSlot::Col(vb)) => {
            let mut out = scratch.take_bool();
            out.reserve(n);
            out.extend(va.iter().zip(&vb).map(|(&x, &y)| f(x, y)));
            scratch.retire_num(va);
            scratch.retire_num(vb);
            BoolSlot::Col(out)
        }
    };
    scratch.bools.push(r);
}

/// Eager boolean AND/OR with constant folding (the reference VM).
fn bin_bool(scratch: &mut VmScratch, and: bool) {
    let b = scratch.pop_bool();
    let a = scratch.pop_bool();
    let r = match (a, b) {
        (BoolSlot::Const(x), BoolSlot::Const(y)) => {
            BoolSlot::Const(if and { x && y } else { x || y })
        }
        (BoolSlot::Const(c), BoolSlot::Col(v))
        | (BoolSlot::Col(v), BoolSlot::Const(c)) => {
            if c == and {
                // true && v == v; false || v == v
                BoolSlot::Col(v)
            } else {
                // false && v == false; true || v == true
                scratch.retire_bool(v);
                BoolSlot::Const(c)
            }
        }
        (BoolSlot::Col(mut va), BoolSlot::Col(vb)) => {
            if and {
                for (x, &y) in va.iter_mut().zip(&vb) {
                    *x = *x && y;
                }
            } else {
                for (x, &y) in va.iter_mut().zip(&vb) {
                    *x = *x || y;
                }
            }
            scratch.retire_bool(vb);
            BoolSlot::Col(va)
        }
    };
    scratch.bools.push(r);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filterexpr::parser::parse;
    use crate::util::Rng;

    /// Expand bitmask words into a bool mask over n rows.
    fn bits_to_bools(bits: &[u64], n: usize) -> Vec<bool> {
        (0..n).map(|i| bits[i / 64] >> (i % 64) & 1 == 1).collect()
    }

    /// Tree-walk oracle vs SIMD VM vs scalar column VM over random
    /// matrices: bit-identical masks, for every expression shape we
    /// support, at page sizes that exercise chunk and word tails.
    #[test]
    fn all_three_evaluators_agree() {
        let exprs = [
            "met > 30",
            "sum_pt / n_tracks > 5",
            "max_pair_mass > 80 && max_pair_mass < 100 && max_pt > 20",
            "n_tracks >= 4 || (met > 30 && ht_frac < 0.8)",
            "abs(max_abs_eta - 2.5) < min(1.0, ht_frac)",
            "!(met > 10) || sqrt(sum_pt) >= 3",
            "true && met / n_tracks > 1",
            "false || -met < -1",
            "max(met, sum_pt) == met",
            "met != met", // always false, exercises Ne
            "2 + 3 * 4 > 13 && met >= 0", // constant folding path
            "total_mass > 50 && (max_pt > 10 || met > 5) && n_tracks < 40",
        ];
        let mut rng = Rng::new(0x600D);
        for src in exprs {
            let expr = parse(src).unwrap();
            let filter =
                crate::filterexpr::CompiledFilter::new(expr.clone()).unwrap();
            let prog = compile(&expr);
            let mut scratch = VmScratch::new();
            let mut mask = Vec::new();
            let mut mask_scalar = Vec::new();
            let mut bits = Vec::new();
            for trial in 0..20 {
                let n = 1 + rng.index(300);
                let feats: Vec<f32> = (0..n * NUM_FEATURES)
                    .map(|_| {
                        // mix of zeros (division edge cases) and values
                        if rng.chance(0.2) {
                            0.0
                        } else {
                            (rng.f32() * 200.0) - 40.0
                        }
                    })
                    .collect();
                prog.eval_into(&feats, n, &mut scratch, &mut mask);
                prog.eval_into_scalar(&feats, n, &mut scratch, &mut mask_scalar);
                prog.eval_bits_into(&feats, n, &mut scratch, &mut bits);
                let oracle: Vec<bool> = (0..n)
                    .map(|i| {
                        filter.accept(
                            &feats[i * NUM_FEATURES..(i + 1) * NUM_FEATURES],
                        )
                    })
                    .collect();
                assert_eq!(mask, oracle, "simd '{src}' trial {trial} n {n}");
                assert_eq!(
                    mask_scalar, oracle,
                    "scalar '{src}' trial {trial} n {n}"
                );
                assert_eq!(
                    bits_to_bools(&bits, n),
                    oracle,
                    "bits '{src}' trial {trial} n {n}"
                );
            }
        }
    }

    #[test]
    fn constant_expressions_broadcast() {
        let expr = parse("true || met > 1").unwrap();
        let prog = compile(&expr);
        let mut scratch = VmScratch::new();
        let mut mask = Vec::new();
        let feats = vec![0f32; 4 * NUM_FEATURES];
        prog.eval_into(&feats, 4, &mut scratch, &mut mask);
        assert_eq!(mask, vec![true; 4]);
        let mut bits = Vec::new();
        prog.eval_bits_into(&feats, 4, &mut scratch, &mut bits);
        assert_eq!(bits, vec![0b1111u64], "broadcast trims past row n");
    }

    #[test]
    fn bitmask_tails_are_trimmed() {
        // `!(met > 10)` flips intermediate tail bits to 1; the final
        // mask must still be clean past row n for every tail shape
        // (word-aligned, chunk-aligned, ragged)
        let expr = parse("!(met > 10)").unwrap();
        let prog = compile(&expr);
        let mut scratch = VmScratch::new();
        let mut bits = Vec::new();
        for n in [1usize, 7, 8, 63, 64, 65, 100, 128, 130] {
            let feats = vec![0f32; n * NUM_FEATURES]; // met=0: all accept
            prog.eval_bits_into(&feats, n, &mut scratch, &mut bits);
            assert_eq!(bits.len(), n.div_ceil(64), "n={n}");
            let ones: u32 = bits.iter().map(|w| w.count_ones()).sum();
            assert_eq!(ones as usize, n, "tail bits leaked at n={n}");
        }
    }

    #[test]
    fn scratch_buffers_are_recycled() {
        let expr = parse("sum_pt / n_tracks > 5 && met > 1").unwrap();
        let prog = compile(&expr);
        let mut scratch = VmScratch::new();
        let mut mask = Vec::new();
        let feats = vec![1f32; 64 * NUM_FEATURES];
        prog.eval_into(&feats, 64, &mut scratch, &mut mask);
        let pooled_nums = scratch.num_pool.len();
        let pooled_masks = scratch.mask_pool.len();
        assert!(pooled_nums > 0);
        assert!(pooled_masks > 0);
        // a second evaluation reuses the pools instead of growing them
        prog.eval_into(&feats, 64, &mut scratch, &mut mask);
        assert_eq!(scratch.num_pool.len(), pooled_nums);
        assert_eq!(scratch.mask_pool.len(), pooled_masks);
        // the scalar reference path recycles its own pools too
        prog.eval_into_scalar(&feats, 64, &mut scratch, &mut mask);
        let pooled_bools = scratch.bool_pool.len();
        assert!(pooled_bools > 0);
        prog.eval_into_scalar(&feats, 64, &mut scratch, &mut mask);
        assert_eq!(scratch.bool_pool.len(), pooled_bools);
    }

    #[test]
    fn postfix_shape() {
        let expr = parse("met + 1 > 2").unwrap();
        let prog = compile(&expr);
        assert_eq!(
            prog.ops(),
            &[
                Op::PushFeat(crate::events::FeatureId::Met as u16),
                Op::PushNum(1.0),
                Op::Add,
                Op::PushNum(2.0),
                Op::Gt,
            ]
        );
    }

    #[test]
    fn repeated_features_compile_to_cached_loads() {
        let expr =
            parse("max_pair_mass > 80 && max_pair_mass < 100 && max_pt > 20")
                .unwrap();
        let prog = compile(&expr);
        let mpm = crate::events::FeatureId::MaxPairMass as u16;
        let mpt = crate::events::FeatureId::MaxPt as u16;
        let cached = prog
            .ops()
            .iter()
            .filter(|op| **op == Op::PushFeatCached(mpm))
            .count();
        assert_eq!(cached, 2, "duplicated feature loads use the cache");
        assert!(prog.ops().contains(&Op::PushFeat(mpt)), "single use stays direct");
        // and the cached program still evaluates correctly
        let mut scratch = VmScratch::new();
        let mut mask = Vec::new();
        let mut feats = vec![0f32; 2 * NUM_FEATURES];
        feats[mpm as usize] = 91.0; // row 0: in the Z window...
        feats[mpt as usize] = 45.0; // ...with a hard track
        feats[NUM_FEATURES + mpm as usize] = 120.0; // row 1: outside
        prog.eval_into(&feats, 2, &mut scratch, &mut mask);
        assert_eq!(mask, vec![true, false]);
    }

    #[test]
    fn zero_rows() {
        let expr = parse("met > 1").unwrap();
        let prog = compile(&expr);
        let mut scratch = VmScratch::new();
        let mut mask = vec![true; 3];
        prog.eval_into(&[], 0, &mut scratch, &mut mask);
        assert!(mask.is_empty());
        let mut bits = vec![7u64];
        prog.eval_bits_into(&[], 0, &mut scratch, &mut bits);
        assert!(bits.is_empty());
    }
}
