//! Flat postfix bytecode + column-at-a-time evaluation — the vectorized
//! replacement for the per-event recursive AST walk on the node hot
//! path.
//!
//! [`compile`] flattens a type-checked [`Expr`] into postfix [`Op`]s.
//! [`Program::eval_into`] then evaluates the whole feature matrix
//! column-at-a-time: every opcode runs **one tight loop** over its
//! operand columns, and the value stack holds whole columns (`Vec<f64>`
//! / `Vec<bool>`) that are recycled through [`VmScratch`] pools, so a
//! steady-state page evaluates with **zero allocations**.
//!
//! Two deliberate semantics choices keep the accept set **bit-identical**
//! to the tree-walk oracle (`CompiledFilter::accept`):
//!
//! - Arithmetic runs in `f64`, exactly like the tree walk (constants are
//!   `f64` literals; features are widened `f32 → f64`). An `f32` stack
//!   would round differently against fractional cut constants.
//! - `&&` / `||` are evaluated eagerly instead of short-circuited. That
//!   is safe because operands are effect-free and every comparison
//!   yields a plain `bool` even for NaN/∞ inputs (e.g. a division the
//!   tree walk would have skipped), so the boolean AND/OR of both sides
//!   equals the short-circuit result. Constant operands still fold:
//!   `false && …` collapses without touching the column.

use crate::events::NUM_FEATURES;
use crate::filterexpr::ast::{BinOp, Expr, Func, UnOp};

/// One postfix opcode. Operand types are fixed per opcode (the AST is
/// type-checked before compilation), so numeric and boolean slots can
/// live on separate stacks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    /// Push a numeric constant.
    PushNum(f64),
    /// Push a boolean constant.
    PushBool(bool),
    /// Push feature column `f` of the feature matrix (gathered directly
    /// into the working slot — emitted when the program references the
    /// feature exactly once).
    PushFeat(u16),
    /// Push feature column `f` via the per-call gather cache — emitted
    /// when the program references the feature more than once, so the
    /// strided gather happens once and later uses are contiguous copies.
    PushFeatCached(u16),
    // numeric → numeric
    Neg,
    Add,
    Sub,
    Mul,
    Div,
    Abs,
    Sqrt,
    Min,
    Max,
    // numeric × numeric → boolean
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
    // boolean → boolean
    Not,
    And,
    Or,
}

/// A compiled filter program: postfix opcodes over a two-typed column
/// stack.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    ops: Vec<Op>,
}

/// Flatten a type-checked expression into postfix bytecode. The caller
/// (`CompiledFilter::new`) guarantees the expression type-checks and
/// references only in-bounds features. Features referenced more than
/// once are rewritten to [`Op::PushFeatCached`] so each column is
/// gathered from the strided matrix only once per page.
pub fn compile(expr: &Expr) -> Program {
    let mut ops = Vec::new();
    emit(expr, &mut ops);
    // common-subexpression pass over feature loads
    let max_feat = ops
        .iter()
        .filter_map(|op| match op {
            Op::PushFeat(f) => Some(*f as usize),
            _ => None,
        })
        .max();
    if let Some(max_feat) = max_feat {
        let mut uses = vec![0u32; max_feat + 1];
        for op in &ops {
            if let Op::PushFeat(f) = op {
                uses[*f as usize] += 1;
            }
        }
        for op in ops.iter_mut() {
            if let Op::PushFeat(f) = *op {
                if uses[f as usize] > 1 {
                    *op = Op::PushFeatCached(f);
                }
            }
        }
    }
    Program { ops }
}

fn emit(e: &Expr, out: &mut Vec<Op>) {
    match e {
        Expr::Num(n) => out.push(Op::PushNum(*n)),
        Expr::Bool(b) => out.push(Op::PushBool(*b)),
        Expr::Feature(f) => out.push(Op::PushFeat(*f)),
        Expr::Un(op, a) => {
            emit(a, out);
            out.push(match op {
                UnOp::Neg => Op::Neg,
                UnOp::Not => Op::Not,
            });
        }
        Expr::Bin(op, a, b) => {
            emit(a, out);
            emit(b, out);
            out.push(match op {
                BinOp::Add => Op::Add,
                BinOp::Sub => Op::Sub,
                BinOp::Mul => Op::Mul,
                BinOp::Div => Op::Div,
                BinOp::Lt => Op::Lt,
                BinOp::Le => Op::Le,
                BinOp::Gt => Op::Gt,
                BinOp::Ge => Op::Ge,
                BinOp::Eq => Op::Eq,
                BinOp::Ne => Op::Ne,
                BinOp::And => Op::And,
                BinOp::Or => Op::Or,
            });
        }
        Expr::Call(f, args) => {
            for a in args {
                emit(a, out);
            }
            out.push(match f {
                Func::Abs => Op::Abs,
                Func::Sqrt => Op::Sqrt,
                Func::Min => Op::Min,
                Func::Max => Op::Max,
            });
        }
    }
}

/// A numeric stack slot: either a broadcast constant or a whole column.
enum NumSlot {
    Const(f64),
    Col(Vec<f64>),
}

/// A boolean stack slot.
enum BoolSlot {
    Const(bool),
    Col(Vec<bool>),
}

/// Reusable evaluation state: the typed value stacks plus buffer pools.
/// Keep one per worker and feed it every page — after the first page no
/// evaluation allocates.
#[derive(Default)]
pub struct VmScratch {
    nums: Vec<NumSlot>,
    bools: Vec<BoolSlot>,
    num_pool: Vec<Vec<f64>>,
    bool_pool: Vec<Vec<bool>>,
    /// per-`eval_into` gather cache for `Op::PushFeatCached`, indexed by
    /// feature id; entries are invalidated (returned to the pool) at the
    /// start of every evaluation
    feat_cache: Vec<Option<Vec<f64>>>,
}

impl VmScratch {
    pub fn new() -> Self {
        VmScratch::default()
    }

    fn take_num(&mut self) -> Vec<f64> {
        let mut v = self.num_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    fn take_bool(&mut self) -> Vec<bool> {
        let mut v = self.bool_pool.pop().unwrap_or_default();
        v.clear();
        v
    }

    fn retire_num(&mut self, v: Vec<f64>) {
        self.num_pool.push(v);
    }

    fn retire_bool(&mut self, v: Vec<bool>) {
        self.bool_pool.push(v);
    }

    fn pop_num(&mut self) -> NumSlot {
        self.nums.pop().expect("typechecked: numeric operand")
    }

    fn pop_bool(&mut self) -> BoolSlot {
        self.bools.pop().expect("typechecked: boolean operand")
    }
}

impl Program {
    pub fn ops(&self) -> &[Op] {
        &self.ops
    }

    /// Evaluate over the first `n` rows of a row-major `(B, NUM_FEATURES)`
    /// feature matrix, writing the accept mask into `out` (cleared
    /// first). `scratch` carries the reusable column buffers.
    pub fn eval_into(
        &self,
        feats: &[f32],
        n: usize,
        scratch: &mut VmScratch,
        out: &mut Vec<bool>,
    ) {
        debug_assert!(n * NUM_FEATURES <= feats.len());
        debug_assert!(scratch.nums.is_empty() && scratch.bools.is_empty());
        // stale gather cache from the previous page goes back to the pool
        for slot in scratch.feat_cache.iter_mut() {
            if let Some(v) = slot.take() {
                scratch.num_pool.push(v);
            }
        }
        for op in &self.ops {
            match *op {
                Op::PushNum(c) => scratch.nums.push(NumSlot::Const(c)),
                Op::PushBool(c) => scratch.bools.push(BoolSlot::Const(c)),
                Op::PushFeat(f) => {
                    let f = f as usize;
                    let mut col = scratch.take_num();
                    col.reserve(n);
                    for i in 0..n {
                        col.push(feats[i * NUM_FEATURES + f] as f64);
                    }
                    scratch.nums.push(NumSlot::Col(col));
                }
                Op::PushFeatCached(f) => {
                    let f = f as usize;
                    if scratch.feat_cache.len() <= f {
                        scratch.feat_cache.resize_with(f + 1, || None);
                    }
                    if scratch.feat_cache[f].is_none() {
                        let mut col = scratch.take_num();
                        col.reserve(n);
                        for i in 0..n {
                            col.push(feats[i * NUM_FEATURES + f] as f64);
                        }
                        scratch.feat_cache[f] = Some(col);
                    }
                    let mut col = scratch.take_num();
                    col.extend_from_slice(
                        scratch.feat_cache[f].as_deref().expect("just filled"),
                    );
                    scratch.nums.push(NumSlot::Col(col));
                }
                Op::Neg => un_num(scratch, |x| -x),
                Op::Abs => un_num(scratch, f64::abs),
                // identical guard to the tree walk: sqrt of a negative
                // intermediate clamps to 0 instead of NaN
                Op::Sqrt => un_num(scratch, |x| x.max(0.0).sqrt()),
                Op::Add => bin_num(scratch, |x, y| x + y),
                Op::Sub => bin_num(scratch, |x, y| x - y),
                Op::Mul => bin_num(scratch, |x, y| x * y),
                Op::Div => bin_num(scratch, |x, y| x / y),
                Op::Min => bin_num(scratch, f64::min),
                Op::Max => bin_num(scratch, f64::max),
                Op::Lt => cmp(scratch, n, |x, y| x < y),
                Op::Le => cmp(scratch, n, |x, y| x <= y),
                Op::Gt => cmp(scratch, n, |x, y| x > y),
                Op::Ge => cmp(scratch, n, |x, y| x >= y),
                Op::Eq => cmp(scratch, n, |x, y| x == y),
                Op::Ne => cmp(scratch, n, |x, y| x != y),
                Op::Not => {
                    let s = scratch.pop_bool();
                    let r = match s {
                        BoolSlot::Const(c) => BoolSlot::Const(!c),
                        BoolSlot::Col(mut v) => {
                            for b in v.iter_mut() {
                                *b = !*b;
                            }
                            BoolSlot::Col(v)
                        }
                    };
                    scratch.bools.push(r);
                }
                Op::And => bin_bool(scratch, true),
                Op::Or => bin_bool(scratch, false),
            }
        }
        out.clear();
        match scratch.pop_bool() {
            BoolSlot::Const(c) => out.resize(n, c),
            BoolSlot::Col(v) => {
                out.extend_from_slice(&v);
                scratch.retire_bool(v);
            }
        }
        debug_assert!(scratch.nums.is_empty() && scratch.bools.is_empty());
    }
}

fn un_num(scratch: &mut VmScratch, f: impl Fn(f64) -> f64) {
    let r = match scratch.pop_num() {
        NumSlot::Const(x) => NumSlot::Const(f(x)),
        NumSlot::Col(mut v) => {
            for x in v.iter_mut() {
                *x = f(*x);
            }
            NumSlot::Col(v)
        }
    };
    scratch.nums.push(r);
}

fn bin_num(scratch: &mut VmScratch, f: impl Fn(f64, f64) -> f64) {
    let b = scratch.pop_num();
    let a = scratch.pop_num();
    let r = match (a, b) {
        (NumSlot::Const(x), NumSlot::Const(y)) => NumSlot::Const(f(x, y)),
        (NumSlot::Const(x), NumSlot::Col(mut v)) => {
            for y in v.iter_mut() {
                *y = f(x, *y);
            }
            NumSlot::Col(v)
        }
        (NumSlot::Col(mut v), NumSlot::Const(y)) => {
            for x in v.iter_mut() {
                *x = f(*x, y);
            }
            NumSlot::Col(v)
        }
        (NumSlot::Col(mut va), NumSlot::Col(vb)) => {
            for (x, &y) in va.iter_mut().zip(&vb) {
                *x = f(*x, y);
            }
            scratch.retire_num(vb);
            NumSlot::Col(va)
        }
    };
    scratch.nums.push(r);
}

fn cmp(scratch: &mut VmScratch, n: usize, f: impl Fn(f64, f64) -> bool) {
    let b = scratch.pop_num();
    let a = scratch.pop_num();
    let r = match (a, b) {
        (NumSlot::Const(x), NumSlot::Const(y)) => BoolSlot::Const(f(x, y)),
        (NumSlot::Const(x), NumSlot::Col(v)) => {
            let mut out = scratch.take_bool();
            out.reserve(n);
            out.extend(v.iter().map(|&y| f(x, y)));
            scratch.retire_num(v);
            BoolSlot::Col(out)
        }
        (NumSlot::Col(v), NumSlot::Const(y)) => {
            let mut out = scratch.take_bool();
            out.reserve(n);
            out.extend(v.iter().map(|&x| f(x, y)));
            scratch.retire_num(v);
            BoolSlot::Col(out)
        }
        (NumSlot::Col(va), NumSlot::Col(vb)) => {
            let mut out = scratch.take_bool();
            out.reserve(n);
            out.extend(va.iter().zip(&vb).map(|(&x, &y)| f(x, y)));
            scratch.retire_num(va);
            scratch.retire_num(vb);
            BoolSlot::Col(out)
        }
    };
    scratch.bools.push(r);
}

/// Eager boolean AND (`and = true`) or OR (`and = false`) with constant
/// folding — a constant absorbing element drops the other column.
fn bin_bool(scratch: &mut VmScratch, and: bool) {
    let b = scratch.pop_bool();
    let a = scratch.pop_bool();
    let r = match (a, b) {
        (BoolSlot::Const(x), BoolSlot::Const(y)) => {
            BoolSlot::Const(if and { x && y } else { x || y })
        }
        (BoolSlot::Const(c), BoolSlot::Col(v))
        | (BoolSlot::Col(v), BoolSlot::Const(c)) => {
            if c == and {
                // true && v == v; false || v == v
                BoolSlot::Col(v)
            } else {
                // false && v == false; true || v == true
                scratch.retire_bool(v);
                BoolSlot::Const(c)
            }
        }
        (BoolSlot::Col(mut va), BoolSlot::Col(vb)) => {
            if and {
                for (x, &y) in va.iter_mut().zip(&vb) {
                    *x = *x && y;
                }
            } else {
                for (x, &y) in va.iter_mut().zip(&vb) {
                    *x = *x || y;
                }
            }
            scratch.retire_bool(vb);
            BoolSlot::Col(va)
        }
    };
    scratch.bools.push(r);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filterexpr::parser::parse;
    use crate::util::Rng;

    /// Tree-walk oracle vs bytecode over random matrices: bit-identical
    /// masks, for every expression shape we support.
    #[test]
    fn bytecode_matches_treewalk_oracle() {
        let exprs = [
            "met > 30",
            "sum_pt / n_tracks > 5",
            "max_pair_mass > 80 && max_pair_mass < 100 && max_pt > 20",
            "n_tracks >= 4 || (met > 30 && ht_frac < 0.8)",
            "abs(max_abs_eta - 2.5) < min(1.0, ht_frac)",
            "!(met > 10) || sqrt(sum_pt) >= 3",
            "true && met / n_tracks > 1",
            "false || -met < -1",
            "max(met, sum_pt) == met",
            "met != met", // always false, exercises Ne
            "2 + 3 * 4 > 13 && met >= 0", // constant folding path
            "total_mass > 50 && (max_pt > 10 || met > 5) && n_tracks < 40",
        ];
        let mut rng = Rng::new(0x600D);
        for src in exprs {
            let expr = parse(src).unwrap();
            let filter =
                crate::filterexpr::CompiledFilter::new(expr.clone()).unwrap();
            let prog = compile(&expr);
            let mut scratch = VmScratch::new();
            let mut mask = Vec::new();
            for trial in 0..20 {
                let n = 1 + rng.index(300);
                let feats: Vec<f32> = (0..n * NUM_FEATURES)
                    .map(|_| {
                        // mix of zeros (division edge cases) and values
                        if rng.chance(0.2) {
                            0.0
                        } else {
                            (rng.f32() * 200.0) - 40.0
                        }
                    })
                    .collect();
                prog.eval_into(&feats, n, &mut scratch, &mut mask);
                let oracle: Vec<bool> = (0..n)
                    .map(|i| {
                        filter.accept(
                            &feats[i * NUM_FEATURES..(i + 1) * NUM_FEATURES],
                        )
                    })
                    .collect();
                assert_eq!(mask, oracle, "'{src}' trial {trial} n {n}");
            }
        }
    }

    #[test]
    fn constant_expressions_broadcast() {
        let expr = parse("true || met > 1").unwrap();
        let prog = compile(&expr);
        let mut scratch = VmScratch::new();
        let mut mask = Vec::new();
        let feats = vec![0f32; 4 * NUM_FEATURES];
        prog.eval_into(&feats, 4, &mut scratch, &mut mask);
        assert_eq!(mask, vec![true; 4]);
    }

    #[test]
    fn scratch_buffers_are_recycled() {
        let expr = parse("sum_pt / n_tracks > 5 && met > 1").unwrap();
        let prog = compile(&expr);
        let mut scratch = VmScratch::new();
        let mut mask = Vec::new();
        let feats = vec![1f32; 64 * NUM_FEATURES];
        prog.eval_into(&feats, 64, &mut scratch, &mut mask);
        let pooled_nums = scratch.num_pool.len();
        let pooled_bools = scratch.bool_pool.len();
        assert!(pooled_nums > 0);
        // a second evaluation reuses the pools instead of growing them
        prog.eval_into(&feats, 64, &mut scratch, &mut mask);
        assert_eq!(scratch.num_pool.len(), pooled_nums);
        assert_eq!(scratch.bool_pool.len(), pooled_bools);
    }

    #[test]
    fn postfix_shape() {
        let expr = parse("met + 1 > 2").unwrap();
        let prog = compile(&expr);
        assert_eq!(
            prog.ops(),
            &[
                Op::PushFeat(crate::events::FeatureId::Met as u16),
                Op::PushNum(1.0),
                Op::Add,
                Op::PushNum(2.0),
                Op::Gt,
            ]
        );
    }

    #[test]
    fn repeated_features_compile_to_cached_loads() {
        let expr =
            parse("max_pair_mass > 80 && max_pair_mass < 100 && max_pt > 20")
                .unwrap();
        let prog = compile(&expr);
        let mpm = crate::events::FeatureId::MaxPairMass as u16;
        let mpt = crate::events::FeatureId::MaxPt as u16;
        let cached = prog
            .ops()
            .iter()
            .filter(|op| **op == Op::PushFeatCached(mpm))
            .count();
        assert_eq!(cached, 2, "duplicated feature loads use the cache");
        assert!(prog.ops().contains(&Op::PushFeat(mpt)), "single use stays direct");
        // and the cached program still evaluates correctly
        let mut scratch = VmScratch::new();
        let mut mask = Vec::new();
        let mut feats = vec![0f32; 2 * NUM_FEATURES];
        feats[mpm as usize] = 91.0; // row 0: in the Z window...
        feats[mpt as usize] = 45.0; // ...with a hard track
        feats[NUM_FEATURES + mpm as usize] = 120.0; // row 1: outside
        prog.eval_into(&feats, 2, &mut scratch, &mut mask);
        assert_eq!(mask, vec![true, false]);
    }

    #[test]
    fn zero_rows() {
        let expr = parse("met > 1").unwrap();
        let prog = compile(&expr);
        let mut scratch = VmScratch::new();
        let mut mask = vec![true; 3];
        prog.eval_into(&[], 0, &mut scratch, &mut mask);
        assert!(mask.is_empty());
    }
}
