//! Filter evaluation: a compiled filter accepts/rejects events by their
//! feature vectors, and can batch-evaluate a whole feature matrix (the
//! node executor's hot path after the kernel runs).
//!
//! Compilation does three things: type-check the AST, bounds-check every
//! referenced feature index against `NUM_FEATURES` (so evaluation can
//! never index past a feature row), and flatten the tree into the
//! postfix [`bytecode`] program that the batch paths execute
//! column-at-a-time. The recursive tree walk survives as
//! [`CompiledFilter::accept`] / [`accept_batch_treewalk`] — the
//! reference oracle the bytecode is tested bit-identical against (and
//! the baseline the hotpath bench compares throughput to).
//!
//! [`bytecode`]: crate::filterexpr::bytecode
//! [`accept_batch_treewalk`]: CompiledFilter::accept_batch_treewalk

use crate::events::NUM_FEATURES;
use crate::filterexpr::ast::{BinOp, Expr, Func, Ty, UnOp};
use crate::filterexpr::bytecode::{self, Program, VmScratch};

#[derive(Debug, Clone, PartialEq)]
pub struct EvalError(pub String);

impl std::fmt::Display for EvalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "filter error: {}", self.0)
    }
}
impl std::error::Error for EvalError {}

/// A type-checked, bounds-checked, ready-to-run filter.
#[derive(Debug, Clone)]
pub struct CompiledFilter {
    expr: Expr,
    program: Program,
    source_ty: Ty,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum V {
    N(f64),
    B(bool),
}

fn eval(expr: &Expr, feats: &[f32]) -> V {
    match expr {
        Expr::Num(n) => V::N(*n),
        Expr::Bool(b) => V::B(*b),
        // in range: CompiledFilter::new rejects indices >= NUM_FEATURES
        Expr::Feature(f) => V::N(feats[*f as usize] as f64),
        Expr::Un(UnOp::Not, e) => match eval(e, feats) {
            V::B(b) => V::B(!b),
            V::N(_) => unreachable!("typechecked"),
        },
        Expr::Un(UnOp::Neg, e) => match eval(e, feats) {
            V::N(n) => V::N(-n),
            V::B(_) => unreachable!("typechecked"),
        },
        Expr::Bin(op, a, b) => {
            match op {
                BinOp::And => {
                    // short-circuit
                    if let V::B(false) = eval(a, feats) {
                        return V::B(false);
                    }
                    return eval(b, feats);
                }
                BinOp::Or => {
                    if let V::B(true) = eval(a, feats) {
                        return V::B(true);
                    }
                    return eval(b, feats);
                }
                _ => {}
            }
            let (x, y) = match (eval(a, feats), eval(b, feats)) {
                (V::N(x), V::N(y)) => (x, y),
                _ => unreachable!("typechecked"),
            };
            match op {
                BinOp::Lt => V::B(x < y),
                BinOp::Le => V::B(x <= y),
                BinOp::Gt => V::B(x > y),
                BinOp::Ge => V::B(x >= y),
                BinOp::Eq => V::B(x == y),
                BinOp::Ne => V::B(x != y),
                BinOp::Add => V::N(x + y),
                BinOp::Sub => V::N(x - y),
                BinOp::Mul => V::N(x * y),
                BinOp::Div => V::N(x / y),
                BinOp::And | BinOp::Or => unreachable!(),
            }
        }
        Expr::Call(f, args) => {
            let n = |i: usize| match eval(&args[i], feats) {
                V::N(n) => n,
                V::B(_) => unreachable!("typechecked"),
            };
            V::N(match f {
                Func::Abs => n(0).abs(),
                Func::Sqrt => n(0).max(0.0).sqrt(),
                Func::Min => n(0).min(n(1)),
                Func::Max => n(0).max(n(1)),
            })
        }
    }
}

impl CompiledFilter {
    /// Typecheck, bounds-check feature references, and compile to
    /// bytecode. A numeric top-level expression is rejected — the submit
    /// form requires a predicate.
    pub fn new(expr: Expr) -> Result<CompiledFilter, EvalError> {
        let ty = expr.check().map_err(|e| EvalError(e.to_string()))?;
        if ty != Ty::Bool {
            return Err(EvalError(
                "filter must be a boolean predicate".into(),
            ));
        }
        // reject out-of-range feature indices at compile time: the
        // parser only produces named (in-range) features, but the AST is
        // public and a programmatic expression must not be able to index
        // past a feature row at evaluation time
        if let Some(f) = expr.max_feature() {
            if f as usize >= NUM_FEATURES {
                return Err(EvalError(format!(
                    "feature index {f} out of range (only {NUM_FEATURES} \
                     features exist)"
                )));
            }
        }
        let program = bytecode::compile(&expr);
        Ok(CompiledFilter { expr, program, source_ty: ty })
    }

    /// Accept/reject one event's feature vector (recursive tree walk —
    /// the reference oracle; batch paths must agree bit for bit).
    pub fn accept(&self, feats: &[f32]) -> bool {
        debug_assert_eq!(feats.len(), NUM_FEATURES);
        debug_assert_eq!(self.source_ty, Ty::Bool);
        match eval(&self.expr, feats) {
            V::B(b) => b,
            V::N(_) => unreachable!("typechecked"),
        }
    }

    /// Batch evaluation over a (B, F) row-major feature matrix. Returns a
    /// selection mask. `n_real` limits evaluation to real (non-padding)
    /// rows. Runs the vectorized bytecode; allocates fresh scratch — the
    /// hot loop should use [`accept_batch_into`] with reused scratch.
    ///
    /// [`accept_batch_into`]: CompiledFilter::accept_batch_into
    pub fn accept_batch(&self, feats: &[f32], n_real: usize) -> Vec<bool> {
        let mut scratch = VmScratch::new();
        let mut out = Vec::new();
        self.accept_batch_into(feats, n_real, &mut scratch, &mut out);
        out
    }

    /// Allocation-free batch evaluation: write the accept mask for the
    /// first `n_real` rows into `out`, recycling `scratch`'s column
    /// buffers across calls. Runs the vectorized (SIMD/chunked) VM.
    pub fn accept_batch_into(
        &self,
        feats: &[f32],
        n_real: usize,
        scratch: &mut VmScratch,
        out: &mut Vec<bool>,
    ) {
        let rows = feats.len() / NUM_FEATURES;
        self.program.eval_into(feats, n_real.min(rows), scratch, out);
    }

    /// Allocation-free batch evaluation in bitmask form: bit `i` of word
    /// `w` in `out` is row `64*w + i`'s accept decision (bits past
    /// `n_real` are zero). This is the node executor's hot path — the
    /// `Vec<bool>` expansion of [`accept_batch_into`] is skipped
    /// entirely.
    ///
    /// [`accept_batch_into`]: CompiledFilter::accept_batch_into
    pub fn accept_batch_bits_into(
        &self,
        feats: &[f32],
        n_real: usize,
        scratch: &mut VmScratch,
        out: &mut Vec<u64>,
    ) {
        let rows = feats.len() / NUM_FEATURES;
        self.program.eval_bits_into(feats, n_real.min(rows), scratch, out);
    }

    /// Batch evaluation via the retained PR-3 scalar column VM — the
    /// differential reference the vectorized path is tested against
    /// (and the bench's "scalar bytecode" baseline).
    pub fn accept_batch_into_scalar(
        &self,
        feats: &[f32],
        n_real: usize,
        scratch: &mut VmScratch,
        out: &mut Vec<bool>,
    ) {
        let rows = feats.len() / NUM_FEATURES;
        self.program.eval_into_scalar(feats, n_real.min(rows), scratch, out);
    }

    /// Batch evaluation via the per-event tree walk — kept as the
    /// reference baseline for oracle tests and the hotpath bench.
    pub fn accept_batch_treewalk(
        &self,
        feats: &[f32],
        n_real: usize,
    ) -> Vec<bool> {
        let rows = feats.len() / NUM_FEATURES;
        (0..n_real.min(rows))
            .map(|i| self.accept(&feats[i * NUM_FEATURES..(i + 1) * NUM_FEATURES]))
            .collect()
    }

    /// The compiled postfix program (bench/introspection).
    pub fn program(&self) -> &Program {
        &self.program
    }

    /// The typechecked source AST (what `qcache` canonicalizes for
    /// fingerprinting — reusing it avoids re-parsing the source).
    pub fn expr(&self) -> &Expr {
        &self.expr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filterexpr::parser::parse;

    fn compile(src: &str) -> CompiledFilter {
        CompiledFilter::new(parse(src).unwrap()).unwrap()
    }

    fn feats(vals: &[(usize, f32)]) -> [f32; NUM_FEATURES] {
        let mut f = [0f32; NUM_FEATURES];
        for (i, v) in vals {
            f[*i] = *v;
        }
        f
    }

    #[test]
    fn arithmetic_and_comparison() {
        let f = compile("sum_pt / n_tracks > 5"); // mean pt cut
        assert!(f.accept(&feats(&[(0, 4.0), (1, 30.0)]))); // 7.5 > 5
        assert!(!f.accept(&feats(&[(0, 10.0), (1, 30.0)])));
    }

    #[test]
    fn short_circuit_and_or() {
        let f = compile("n_tracks > 0 && met / n_tracks > 1");
        // n_tracks = 0: short-circuits before the division
        assert!(!f.accept(&feats(&[])));
        let g = compile("true || met / n_tracks > 1");
        assert!(g.accept(&feats(&[])));
    }

    #[test]
    fn functions() {
        let f = compile("abs(max_abs_eta - 2.0) < 0.5");
        assert!(f.accept(&feats(&[(6, 2.3)])));
        assert!(!f.accept(&feats(&[(6, 3.0)])));
        let g = compile("sqrt(met) >= 3");
        assert!(g.accept(&feats(&[(3, 9.0)])));
        let h = compile("max(met, sum_pt) == 7");
        assert!(h.accept(&feats(&[(3, 7.0), (1, 2.0)])));
    }

    #[test]
    fn numeric_toplevel_rejected() {
        let e = parse("met + 1").unwrap();
        assert!(CompiledFilter::new(e).is_err());
    }

    #[test]
    fn out_of_range_feature_rejected_at_compile_time() {
        // the parser cannot produce this, but the AST is public — an
        // index past the feature vector must fail compilation, not
        // panic during evaluation on the node
        let e = Expr::Bin(
            BinOp::Gt,
            Box::new(Expr::Feature(NUM_FEATURES as u16)),
            Box::new(Expr::Num(1.0)),
        );
        let err = CompiledFilter::new(e).unwrap_err();
        assert!(err.0.contains("out of range"), "{err}");
        let far = Expr::Bin(
            BinOp::Lt,
            Box::new(Expr::Call(
                Func::Min,
                vec![Expr::Feature(0), Expr::Feature(40_000)],
            )),
            Box::new(Expr::Num(1.0)),
        );
        assert!(CompiledFilter::new(far).is_err());
        // the boundary index is fine
        let ok = Expr::Bin(
            BinOp::Gt,
            Box::new(Expr::Feature(NUM_FEATURES as u16 - 1)),
            Box::new(Expr::Num(1.0)),
        );
        assert!(CompiledFilter::new(ok).is_ok());
    }

    #[test]
    fn batch_respects_n_real() {
        let f = compile("met > 1");
        let mut m = vec![0f32; 4 * NUM_FEATURES];
        for row in 0..4 {
            m[row * NUM_FEATURES + 3] = 2.0; // met = 2 everywhere
        }
        let mask = f.accept_batch(&m, 2);
        assert_eq!(mask, vec![true, true]); // padding rows not evaluated
        assert_eq!(f.accept_batch_treewalk(&m, 2), mask);
    }

    #[test]
    fn bytecode_and_treewalk_agree_on_division_by_zero() {
        // the tree walk short-circuits past the division; the bytecode
        // evaluates it eagerly (inf/NaN) — accept sets must still match
        let f = compile("n_tracks > 0 && met / n_tracks > 1");
        let mut m = vec![0f32; 3 * NUM_FEATURES];
        m[NUM_FEATURES] = 2.0; // row 1: n_tracks = 2
        m[NUM_FEATURES + 3] = 6.0; // row 1: met = 6 -> 3 > 1
        m[2 * NUM_FEATURES + 3] = 5.0; // row 2: n_tracks = 0, met = 5
        assert_eq!(f.accept_batch(&m, 3), vec![false, true, false]);
        assert_eq!(f.accept_batch_treewalk(&m, 3), f.accept_batch(&m, 3));
    }

    #[test]
    fn not_operator() {
        let f = compile("!(met > 10)");
        assert!(f.accept(&feats(&[(3, 5.0)])));
        assert!(!f.accept(&feats(&[(3, 20.0)])));
    }
}
