//! Fixed-width chunk kernels for the vectorized filter VM — the one
//! place per-opcode inner loops live, in two interchangeable builds:
//!
//! - **`--features simd`** (nightly): explicit `std::simd` `f64x8`
//!   arithmetic and masked compares, each compare emitting its lane
//!   bitmask directly via `Mask::to_bitmask`.
//! - **default** (stable): the same loops written over fixed `[f64; 8]`
//!   chunks so LLVM's autovectorizer produces equivalent code — no
//!   nightly feature, no intrinsics.
//!
//! Either way the semantics contract is identical and deliberately
//! conservative, because the accept set must stay **bit-identical** to
//! the scalar VM and the tree-walk oracle:
//!
//! - `+ - * /` and the six comparisons are lane-wise IEEE-754 f64 ops —
//!   exactly what the scalar paths compute, in the same order, with no
//!   reassociation, FMA contraction, or fast-math.
//! - `min`/`max`/`sqrt` are NOT given explicit SIMD forms even under
//!   the feature flag: `f64::min`/`f64::max` leave the sign of a
//!   `min(-0.0, +0.0)` result platform-defined, and a SIMD intrinsic is
//!   allowed to pick the other zero than the scalar op on the same
//!   machine. A signed zero escaping through `1 / min(a, b)` flips the
//!   infinity it produces, so those opcodes always run the exact scalar
//!   std calls per lane (see [`super::bytecode`]); LLVM may still
//!   vectorize them when that preserves semantics.
//!
//! Comparisons write **bitmasks** (`u64` words, bit `i` of word `w` =
//! row `64*w + i`), not `Vec<bool>`: one word carries 64 rows, so the
//! boolean algebra above the compares (`&& || !`) collapses to word
//! ops at 64 rows per instruction.

/// Lane width of one chunk. Compares assemble 8 chunk masks into each
/// 64-row output word; page tails shorter than a chunk fall back to
/// per-row loops.
pub const LANES: usize = 8;

/// Number of `u64` mask words covering `n` rows.
#[inline]
pub fn mask_words(n: usize) -> usize {
    n.div_ceil(64)
}

/// Clear the unused high bits of the last mask word so popcounts and
/// drains never see garbage past row `n`. Intermediate words are allowed
/// dirty tails (a `Not` flips them to 1); only the final mask is washed.
#[inline]
pub fn trim_mask(words: &mut [u64], n: usize) {
    if let Some(last) = words.last_mut() {
        let used = n - (words.len() - 1) * 64;
        if used < 64 {
            *last &= (1u64 << used) - 1;
        }
    }
}

/// Binary arithmetic opcodes with explicit chunk kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArithOp {
    Add,
    Sub,
    Mul,
    Div,
}

impl ArithOp {
    #[inline(always)]
    pub fn apply(self, x: f64, y: f64) -> f64 {
        match self {
            ArithOp::Add => x + y,
            ArithOp::Sub => x - y,
            ArithOp::Mul => x * y,
            ArithOp::Div => x / y,
        }
    }
}

/// Comparison opcodes; every one produces a bitmask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

impl CmpOp {
    #[inline(always)]
    pub fn apply(self, x: f64, y: f64) -> bool {
        match self {
            CmpOp::Lt => x < y,
            CmpOp::Le => x <= y,
            CmpOp::Gt => x > y,
            CmpOp::Ge => x >= y,
            CmpOp::Eq => x == y,
            CmpOp::Ne => x != y,
        }
    }
}

// ---------------------------------------------------------------------
// explicit std::simd build
// ---------------------------------------------------------------------

#[cfg(feature = "simd")]
mod imp {
    use super::{ArithOp, CmpOp, LANES};
    use std::simd::cmp::{SimdPartialEq, SimdPartialOrd};
    use std::simd::f64x8;

    #[inline(always)]
    fn arith8(op: ArithOp, x: f64x8, y: f64x8) -> f64x8 {
        match op {
            ArithOp::Add => x + y,
            ArithOp::Sub => x - y,
            ArithOp::Mul => x * y,
            ArithOp::Div => x / y,
        }
    }

    #[inline(always)]
    fn cmp8(op: CmpOp, x: f64x8, y: f64x8) -> u64 {
        let m = match op {
            CmpOp::Lt => x.simd_lt(y),
            CmpOp::Le => x.simd_le(y),
            CmpOp::Gt => x.simd_gt(y),
            CmpOp::Ge => x.simd_ge(y),
            CmpOp::Eq => x.simd_eq(y),
            CmpOp::Ne => x.simd_ne(y),
        };
        m.to_bitmask()
    }

    /// `a[i] = op(a[i], b[i])`.
    pub fn arith_col_col(op: ArithOp, a: &mut [f64], b: &[f64]) {
        let n = a.len().min(b.len());
        let chunks = n / LANES;
        for c in 0..chunks {
            let at = c * LANES;
            let x = f64x8::from_slice(&a[at..at + LANES]);
            let y = f64x8::from_slice(&b[at..at + LANES]);
            arith8(op, x, y).copy_to_slice(&mut a[at..at + LANES]);
        }
        for i in chunks * LANES..n {
            a[i] = op.apply(a[i], b[i]);
        }
    }

    /// `a[i] = op(a[i], k)`.
    pub fn arith_col_const(op: ArithOp, a: &mut [f64], k: f64) {
        let y = f64x8::splat(k);
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let at = c * LANES;
            let x = f64x8::from_slice(&a[at..at + LANES]);
            arith8(op, x, y).copy_to_slice(&mut a[at..at + LANES]);
        }
        for x in &mut a[chunks * LANES..] {
            *x = op.apply(*x, k);
        }
    }

    /// `a[i] = op(k, a[i])` (non-commutative ops need this side too).
    pub fn arith_const_col(op: ArithOp, k: f64, a: &mut [f64]) {
        let x = f64x8::splat(k);
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let at = c * LANES;
            let y = f64x8::from_slice(&a[at..at + LANES]);
            arith8(op, x, y).copy_to_slice(&mut a[at..at + LANES]);
        }
        for y in &mut a[chunks * LANES..] {
            *y = op.apply(k, *y);
        }
    }

    #[inline(always)]
    fn load8(v: &[f64], at: usize) -> f64x8 {
        f64x8::from_slice(&v[at..at + LANES])
    }

    /// Compare two columns into bitmask words (`out` is overwritten; its
    /// tail bits past `a.len()` are zero).
    pub fn cmp_col_col(op: CmpOp, a: &[f64], b: &[f64], out: &mut Vec<u64>) {
        let n = a.len().min(b.len());
        cmp_words(
            n,
            out,
            |at| cmp8(op, load8(a, at), load8(b, at)),
            |i| op.apply(a[i], b[i]),
        );
    }

    /// Compare a column against a broadcast constant.
    pub fn cmp_col_const(op: CmpOp, a: &[f64], k: f64, out: &mut Vec<u64>) {
        let y = f64x8::splat(k);
        cmp_words(
            a.len(),
            out,
            |at| cmp8(op, load8(a, at), y),
            |i| op.apply(a[i], k),
        );
    }

    /// Compare a broadcast constant against a column.
    pub fn cmp_const_col(op: CmpOp, k: f64, a: &[f64], out: &mut Vec<u64>) {
        let x = f64x8::splat(k);
        cmp_words(
            a.len(),
            out,
            |at| cmp8(op, x, load8(a, at)),
            |i| op.apply(k, a[i]),
        );
    }

    /// Assemble n rows of compare results into 64-bit words: eight
    /// 8-lane chunk masks per word, per-row at the tail.
    #[inline(always)]
    fn cmp_words(
        n: usize,
        out: &mut Vec<u64>,
        chunk_bits: impl Fn(usize) -> u64,
        row_bit: impl Fn(usize) -> bool,
    ) {
        out.clear();
        out.resize(super::mask_words(n), 0);
        let chunks = n / LANES;
        for c in 0..chunks {
            out[c * LANES / 64] |= chunk_bits(c * LANES) << (c * LANES % 64);
        }
        for i in chunks * LANES..n {
            out[i / 64] |= (row_bit(i) as u64) << (i % 64);
        }
    }
}

// ---------------------------------------------------------------------
// stable build: fixed-width chunks, written for the autovectorizer
// ---------------------------------------------------------------------

#[cfg(not(feature = "simd"))]
mod imp {
    use super::{ArithOp, CmpOp, LANES};

    /// `a[i] = op(a[i], b[i])`. Plain zip loops with the operator
    /// hoisted out of the loop: each arm is a single IEEE op per
    /// element with no calls, the shape LLVM's autovectorizer turns
    /// into packed f64 arithmetic.
    pub fn arith_col_col(op: ArithOp, a: &mut [f64], b: &[f64]) {
        match op {
            ArithOp::Add => a.iter_mut().zip(b).for_each(|(x, &y)| *x += y),
            ArithOp::Sub => a.iter_mut().zip(b).for_each(|(x, &y)| *x -= y),
            ArithOp::Mul => a.iter_mut().zip(b).for_each(|(x, &y)| *x *= y),
            ArithOp::Div => a.iter_mut().zip(b).for_each(|(x, &y)| *x /= y),
        }
    }

    /// `a[i] = op(a[i], k)`.
    pub fn arith_col_const(op: ArithOp, a: &mut [f64], k: f64) {
        match op {
            ArithOp::Add => a.iter_mut().for_each(|x| *x += k),
            ArithOp::Sub => a.iter_mut().for_each(|x| *x -= k),
            ArithOp::Mul => a.iter_mut().for_each(|x| *x *= k),
            ArithOp::Div => a.iter_mut().for_each(|x| *x /= k),
        }
    }

    /// `a[i] = op(k, a[i])` (non-commutative ops need this side too).
    pub fn arith_const_col(op: ArithOp, k: f64, a: &mut [f64]) {
        match op {
            ArithOp::Add => a.iter_mut().for_each(|y| *y = k + *y),
            ArithOp::Sub => a.iter_mut().for_each(|y| *y = k - *y),
            ArithOp::Mul => a.iter_mut().for_each(|y| *y = k * *y),
            ArithOp::Div => a.iter_mut().for_each(|y| *y = k / *y),
        }
    }

    /// One 8-row chunk of compare bits; `f` is monomorphized per
    /// comparison so the inner loop is branch-free.
    #[inline(always)]
    fn bits8(f: impl Fn(usize) -> bool, at: usize) -> u64 {
        let mut bits = 0u64;
        for l in 0..LANES {
            bits |= (f(at + l) as u64) << l;
        }
        bits
    }

    /// Compare two columns into bitmask words (`out` is overwritten; its
    /// tail bits past `a.len()` are zero).
    pub fn cmp_col_col(op: CmpOp, a: &[f64], b: &[f64], out: &mut Vec<u64>) {
        let n = a.len().min(b.len());
        cmp_words(n, out, |i| op.apply(a[i], b[i]));
    }

    /// Compare a column against a broadcast constant.
    pub fn cmp_col_const(op: CmpOp, a: &[f64], k: f64, out: &mut Vec<u64>) {
        cmp_words(a.len(), out, |i| op.apply(a[i], k));
    }

    /// Compare a broadcast constant against a column.
    pub fn cmp_const_col(op: CmpOp, k: f64, a: &[f64], out: &mut Vec<u64>) {
        cmp_words(a.len(), out, |i| op.apply(k, a[i]));
    }

    /// Assemble n rows of compare results into 64-bit words: eight
    /// 8-row chunks per word, per-row at the tail.
    #[inline(always)]
    fn cmp_words(
        n: usize,
        out: &mut Vec<u64>,
        row_bit: impl Fn(usize) -> bool,
    ) {
        out.clear();
        out.resize(super::mask_words(n), 0);
        let chunks = n / LANES;
        for c in 0..chunks {
            let at = c * LANES;
            out[at / 64] |= bits8(&row_bit, at) << (at % 64);
        }
        for i in chunks * LANES..n {
            out[i / 64] |= (row_bit(i) as u64) << (i % 64);
        }
    }
}

pub use imp::{
    arith_col_col, arith_col_const, arith_const_col, cmp_col_col,
    cmp_col_const, cmp_const_col,
};
