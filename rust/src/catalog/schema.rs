//! The concrete GEPS relations (the paper's PgSQL schema, §4.2) and the
//! [`Catalog`] facade: jobs, nodes, bricks, results — with optional WAL
//! persistence and the broker poll cursor.

use crate::brick::BrickId;
use crate::catalog::index::Index;
use crate::catalog::store::{RowId, Table};
use crate::catalog::wal::Wal;
use crate::util::json::Json;
use std::path::Path;

/// Job lifecycle states, mirroring GRAM's PENDING/ACTIVE/DONE/FAILED plus
/// GEPS phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Submitted,
    Staging,
    Running,
    Merging,
    Done,
    Failed,
    /// terminated on user request before completing (portal cancel)
    Cancelled,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Submitted => "SUBMITTED",
            JobStatus::Staging => "STAGING",
            JobStatus::Running => "RUNNING",
            JobStatus::Merging => "MERGING",
            JobStatus::Done => "DONE",
            JobStatus::Failed => "FAILED",
            JobStatus::Cancelled => "CANCELLED",
        }
    }

    pub fn by_name(s: &str) -> Option<JobStatus> {
        Some(match s {
            "SUBMITTED" => JobStatus::Submitted,
            "STAGING" => JobStatus::Staging,
            "RUNNING" => JobStatus::Running,
            "MERGING" => JobStatus::Merging,
            "DONE" => JobStatus::Done,
            "FAILED" => JobStatus::Failed,
            "CANCELLED" => JobStatus::Cancelled,
            _ => return None,
        })
    }

    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            JobStatus::Done | JobStatus::Failed | JobStatus::Cancelled
        )
    }
}

/// A job specification tuple (what the portal's submit form writes, §5).
#[derive(Debug, Clone, PartialEq)]
pub struct JobRow {
    pub dataset: u32,
    /// user filter expression, e.g. "max_pair_mass > 80 && max_pt > 20"
    pub filter_expr: String,
    pub policy: String,
    pub status: JobStatus,
    /// events selected / processed (filled as results arrive)
    pub events_processed: u64,
    pub events_selected: u64,
    pub error: Option<String>,
}

/// Grid-node registry row (what GRIS publishes, §4.3).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeRow {
    pub name: String,
    pub speed: f64,
    pub slots: usize,
    pub up: bool,
}

/// Brick location row.
#[derive(Debug, Clone, PartialEq)]
pub struct BrickRow {
    pub brick: BrickId,
    pub n_events: u64,
    pub bytes: u64,
    pub holders: Vec<String>,
    /// qcache invalidation epoch: bumped **only when the brick's event
    /// data changes** (ingest, rewrite). Holder-list rewrites —
    /// re-replication, join-time rebalancing, membership churn — copy
    /// the same bytes elsewhere and must NOT touch it, so cached
    /// results keyed on `(brick, epoch)` survive placement changes.
    pub content_epoch: u64,
}

/// Per-task result row.
#[derive(Debug, Clone, PartialEq)]
pub struct ResultRow {
    pub job: RowId,
    pub node: String,
    pub brick: BrickId,
    pub events_in: u64,
    pub events_selected: u64,
    pub result_bytes: u64,
}

// WAL tags
const TAG_JOB: u8 = 1;
const TAG_NODE: u8 = 2;
const TAG_BRICK: u8 = 3;
const TAG_RESULT: u8 = 4;
const TAG_JOB_UPDATE: u8 = 5;
/// holder-list rewrite (re-replication / rebalancing). Replays as an
/// in-place update — logging these as TAG_BRICK used to insert a
/// duplicate brick row on every recovery.
const TAG_BRICK_UPDATE: u8 = 6;
/// content-epoch bump (brick *data* changed — qcache invalidation).
/// Replays in place; deliberately separate from TAG_BRICK_UPDATE so a
/// recovery replay of placement churn can never invalidate caches.
const TAG_BRICK_EPOCH: u8 = 7;

/// The single declared registry of WAL record tags. `gepslint`'s
/// `wal-tag-registry` pass cross-checks it against the `TAG_*` consts
/// above (every const listed exactly once, all bytes unique, no tag
/// declared outside this file) — WAL replay dispatches on these bytes,
/// so a collision or skew silently corrupts recovery.
pub const WAL_TAGS: &[(u8, &str)] = &[
    (TAG_JOB, "job"),
    (TAG_NODE, "node"),
    (TAG_BRICK, "brick"),
    (TAG_RESULT, "result"),
    (TAG_JOB_UPDATE, "job_update"),
    (TAG_BRICK_UPDATE, "brick_update"),
    (TAG_BRICK_EPOCH, "brick_epoch"),
];

fn job_to_json(id: RowId, j: &JobRow) -> Json {
    Json::obj()
        .set("id", id)
        .set("dataset", j.dataset as u64)
        .set("filter", j.filter_expr.as_str())
        .set("policy", j.policy.as_str())
        .set("status", j.status.name())
        .set("processed", j.events_processed)
        .set("selected", j.events_selected)
        .set(
            "error",
            j.error.clone().map(Json::Str).unwrap_or(Json::Null),
        )
}

fn job_from_json(j: &Json) -> Option<(RowId, JobRow)> {
    Some((
        j.get("id")?.as_u64()?,
        JobRow {
            dataset: j.get("dataset")?.as_u64()? as u32,
            filter_expr: j.get("filter")?.as_str()?.to_string(),
            policy: j.get("policy")?.as_str()?.to_string(),
            status: JobStatus::by_name(j.get("status")?.as_str()?)?,
            events_processed: j.get("processed")?.as_u64()?,
            events_selected: j.get("selected")?.as_u64()?,
            error: j.get("error").and_then(|e| e.as_str()).map(String::from),
        },
    ))
}

/// The metadata catalogue.
pub struct Catalog {
    pub jobs: Table<JobRow>,
    pub nodes: Table<NodeRow>,
    pub bricks: Table<BrickRow>,
    pub results: Table<ResultRow>,
    /// secondary index: job id -> result rows (kept by record_result)
    results_by_job: Index<RowId>,
    wal: Option<Wal>,
}

impl Default for Catalog {
    fn default() -> Self {
        Self::new()
    }
}

impl Catalog {
    /// In-memory catalogue (tests, DES).
    pub fn new() -> Self {
        Catalog {
            jobs: Table::new(),
            nodes: Table::new(),
            bricks: Table::new(),
            results: Table::new(),
            results_by_job: Index::new(),
            wal: None,
        }
    }

    /// Durable catalogue: replays the WAL at `path`.
    pub fn open(path: &Path) -> std::io::Result<Self> {
        let (wal, records) = Wal::open(path)?;
        let mut cat = Catalog::new();
        for rec in records {
            let j = match Json::parse(
                std::str::from_utf8(&rec.payload).unwrap_or(""),
            ) {
                Ok(j) => j,
                Err(_) => continue,
            };
            match rec.tag {
                TAG_JOB => {
                    if let Some((id, row)) = job_from_json(&j) {
                        cat.jobs.insert_with_id(id, row);
                    }
                }
                TAG_JOB_UPDATE => {
                    if let Some((id, row)) = job_from_json(&j) {
                        if cat.jobs.get(id).is_some() {
                            cat.jobs.update(id, |r| *r = row);
                        }
                    }
                }
                TAG_NODE => {
                    if let (Some(name), Some(speed), Some(slots)) = (
                        j.get("name").and_then(|v| v.as_str()),
                        j.get("speed").and_then(|v| v.as_f64()),
                        j.get("slots").and_then(|v| v.as_u64()),
                    ) {
                        cat.nodes.insert(NodeRow {
                            name: name.to_string(),
                            speed,
                            slots: slots as usize,
                            up: true,
                        });
                    }
                }
                TAG_BRICK => {
                    if let (Some(ds), Some(seq), Some(n), Some(b)) = (
                        j.get("dataset").and_then(|v| v.as_u64()),
                        j.get("seq").and_then(|v| v.as_u64()),
                        j.get("n_events").and_then(|v| v.as_u64()),
                        j.get("bytes").and_then(|v| v.as_u64()),
                    ) {
                        let holders = j
                            .get("holders")
                            .and_then(|h| h.as_arr())
                            .map(|a| {
                                a.iter()
                                    .filter_map(|x| x.as_str())
                                    .map(String::from)
                                    .collect()
                            })
                            .unwrap_or_default();
                        cat.bricks.insert(BrickRow {
                            brick: BrickId::new(ds as u32, seq as u32),
                            n_events: n,
                            bytes: b,
                            holders,
                            // pre-epoch WAL records replay at epoch 1
                            content_epoch: j
                                .get("epoch")
                                .and_then(|v| v.as_u64())
                                .unwrap_or(1),
                        });
                    }
                }
                TAG_BRICK_EPOCH => {
                    if let (Some(ds), Some(seq), Some(epoch)) = (
                        j.get("dataset").and_then(|v| v.as_u64()),
                        j.get("seq").and_then(|v| v.as_u64()),
                        j.get("epoch").and_then(|v| v.as_u64()),
                    ) {
                        let brick = BrickId::new(ds as u32, seq as u32);
                        let ids: Vec<RowId> = cat
                            .bricks
                            .iter()
                            .filter(|(_, b)| b.brick == brick)
                            .map(|(id, _)| id)
                            .collect();
                        for id in ids {
                            cat.bricks
                                .update(id, |b| b.content_epoch = epoch);
                        }
                    }
                }
                TAG_BRICK_UPDATE => {
                    if let (Some(ds), Some(seq), Some(hs)) = (
                        j.get("dataset").and_then(|v| v.as_u64()),
                        j.get("seq").and_then(|v| v.as_u64()),
                        j.get("holders").and_then(|h| h.as_arr()),
                    ) {
                        let brick = BrickId::new(ds as u32, seq as u32);
                        let holders: Vec<String> = hs
                            .iter()
                            .filter_map(|x| x.as_str())
                            .map(String::from)
                            .collect();
                        let ids: Vec<RowId> = cat
                            .bricks
                            .iter()
                            .filter(|(_, b)| b.brick == brick)
                            .map(|(id, _)| id)
                            .collect();
                        for id in ids {
                            cat.bricks
                                .update(id, |b| b.holders = holders.clone());
                        }
                    }
                }
                TAG_RESULT => {
                    if let (Some(job), Some(node)) = (
                        j.get("job").and_then(|v| v.as_u64()),
                        j.get("node").and_then(|v| v.as_str()),
                    ) {
                        let job_key = job;
                        let rid = cat.results.insert(ResultRow {
                            job,
                            node: node.to_string(),
                            brick: BrickId::new(
                                j.get("ds").and_then(|v| v.as_u64()).unwrap_or(0)
                                    as u32,
                                j.get("seq").and_then(|v| v.as_u64()).unwrap_or(0)
                                    as u32,
                            ),
                            events_in: j
                                .get("in")
                                .and_then(|v| v.as_u64())
                                .unwrap_or(0),
                            events_selected: j
                                .get("sel")
                                .and_then(|v| v.as_u64())
                                .unwrap_or(0),
                            result_bytes: j
                                .get("bytes")
                                .and_then(|v| v.as_u64())
                                .unwrap_or(0),
                        });
                        cat.results_by_job.insert(job_key, rid);
                    }
                }
                _ => {}
            }
        }
        cat.wal = Some(wal);
        Ok(cat)
    }

    fn log(&mut self, tag: u8, j: &Json) {
        if let Some(w) = &mut self.wal {
            // WAL write failure is fatal for durability; surface loudly.
            w.append(tag, j.to_string().as_bytes())
                .expect("WAL append failed");
        }
    }

    /// Submit a job tuple (portal → catalogue). Returns the job id.
    pub fn submit_job(
        &mut self,
        dataset: u32,
        filter_expr: &str,
        policy: &str,
    ) -> RowId {
        let row = JobRow {
            dataset,
            filter_expr: filter_expr.to_string(),
            policy: policy.to_string(),
            status: JobStatus::Submitted,
            events_processed: 0,
            events_selected: 0,
            error: None,
        };
        let id = self.jobs.insert(row.clone());
        let j = job_to_json(id, &row);
        self.log(TAG_JOB, &j);
        id
    }

    /// Update a job row (status / counters).
    pub fn update_job(&mut self, id: RowId, f: impl FnOnce(&mut JobRow)) -> bool {
        let ok = self.jobs.update(id, f);
        if ok {
            if let Some(row) = self.jobs.get(id) {
                let j = job_to_json(id, &row.clone());
                self.log(TAG_JOB_UPDATE, &j);
            }
        }
        ok
    }

    pub fn register_node(&mut self, name: &str, speed: f64, slots: usize) -> RowId {
        let id = self.nodes.insert(NodeRow {
            name: name.to_string(),
            speed,
            slots,
            up: true,
        });
        let j = Json::obj()
            .set("name", name)
            .set("speed", speed)
            .set("slots", slots);
        self.log(TAG_NODE, &j);
        id
    }

    pub fn insert_brick(
        &mut self,
        brick: BrickId,
        n_events: u64,
        bytes: u64,
        holders: Vec<String>,
    ) -> RowId {
        let j = Json::obj()
            .set("dataset", brick.dataset as u64)
            .set("seq", brick.seq as u64)
            .set("n_events", n_events)
            .set("bytes", bytes)
            .set("epoch", 1u64)
            .set(
                "holders",
                Json::Arr(holders.iter().map(|h| Json::Str(h.clone())).collect()),
            );
        let id = self.bricks.insert(BrickRow {
            brick,
            n_events,
            bytes,
            holders,
            content_epoch: 1,
        });
        self.log(TAG_BRICK, &j);
        id
    }

    /// The brick's *data* changed (ingest / rewrite): advance its
    /// content epoch, WAL-durably, and return the new value. Cached
    /// query results keyed on the old epoch stop matching — exactly
    /// this brick, nothing else. Placement changes must use
    /// [`Catalog::set_brick_holders`] instead, which leaves the epoch
    /// alone. Returns `None` for unknown bricks.
    pub fn bump_content_epoch(&mut self, brick: BrickId) -> Option<u64> {
        let ids: Vec<RowId> = self
            .bricks
            .iter()
            .filter(|(_, b)| b.brick == brick)
            .map(|(id, _)| id)
            .collect();
        if ids.is_empty() {
            return None;
        }
        let next = ids
            .iter()
            .filter_map(|id| self.bricks.get(*id))
            .map(|b| b.content_epoch)
            .max()
            .unwrap_or(0)
            + 1;
        for id in ids {
            self.bricks.update(id, |b| b.content_epoch = next);
        }
        let j = Json::obj()
            .set("dataset", brick.dataset as u64)
            .set("seq", brick.seq as u64)
            .set("epoch", next);
        self.log(TAG_BRICK_EPOCH, &j);
        Some(next)
    }

    /// `(brick, content_epoch)` pairs for a dataset, sorted by brick id
    /// — the epoch vector a full-result cache key hashes.
    pub fn brick_epochs(&self, dataset: u32) -> Vec<(BrickId, u64)> {
        let mut out: Vec<(BrickId, u64)> = self
            .bricks
            .iter()
            .filter(|(_, b)| b.brick.dataset == dataset)
            .map(|(_, b)| (b.brick, b.content_epoch))
            .collect();
        out.sort();
        out
    }

    pub fn record_result(&mut self, row: ResultRow) -> RowId {
        let j = Json::obj()
            .set("job", row.job)
            .set("node", row.node.as_str())
            .set("ds", row.brick.dataset as u64)
            .set("seq", row.brick.seq as u64)
            .set("in", row.events_in)
            .set("sel", row.events_selected)
            .set("bytes", row.result_bytes);
        let job = row.job;
        let id = self.results.insert(row);
        self.results_by_job.insert(job, id);
        self.log(TAG_RESULT, &j);
        id
    }

    /// The broker poll: jobs changed since the cursor that are in
    /// Submitted state. Returns (new_cursor, job ids).
    pub fn poll_new_jobs(&self, cursor: u64) -> (u64, Vec<RowId>) {
        let new_cursor = self.jobs.version();
        let ids = self
            .jobs
            .changed_since(cursor)
            .into_iter()
            .filter(|(_, r)| r.status == JobStatus::Submitted)
            .map(|(id, _)| id)
            .collect();
        (new_cursor, ids)
    }

    /// All results for a job — served from the secondary index.
    pub fn job_results(&self, job: RowId) -> Vec<&ResultRow> {
        self.results_by_job
            .get(&job)
            .iter()
            .filter_map(|id| self.results.get(*id))
            .collect()
    }

    /// Atomically replace a brick's holder list (re-replication
    /// recovery §7, join-time rebalancing): the in-memory row and the
    /// WAL record are written under the same `&mut self` critical
    /// section, so a recovery replay always sees either the old or the
    /// new holder set, never a partial one.
    pub fn set_brick_holders(
        &mut self,
        brick: BrickId,
        holders: Vec<String>,
    ) -> bool {
        let ids: Vec<u64> = self
            .bricks
            .iter()
            .filter(|(_, b)| b.brick == brick)
            .map(|(id, _)| id)
            .collect();
        let mut ok = false;
        for id in ids {
            ok |= self.bricks.update(id, |b| b.holders = holders.clone());
        }
        if ok {
            let j = Json::obj()
                .set("dataset", brick.dataset as u64)
                .set("seq", brick.seq as u64)
                .set(
                    "holders",
                    Json::Arr(
                        holders.iter().map(|h| Json::Str(h.clone())).collect(),
                    ),
                );
            self.log(TAG_BRICK_UPDATE, &j);
        }
        ok
    }

    /// Brick states for a dataset in scheduler form.
    pub fn bricks_for_dataset(&self, dataset: u32) -> Vec<crate::scheduler::BrickState> {
        self.bricks
            .iter()
            .filter(|(_, b)| b.brick.dataset == dataset)
            .map(|(_, b)| crate::scheduler::BrickState {
                id: b.brick,
                n_events: b.n_events as usize,
                bytes: b.bytes,
                holders: b.holders.clone(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wal_tags_registry_is_complete_and_unique() {
        let mut bytes: Vec<u8> = WAL_TAGS.iter().map(|(b, _)| *b).collect();
        bytes.sort_unstable();
        bytes.dedup();
        assert_eq!(bytes.len(), WAL_TAGS.len(), "duplicate WAL tag byte");
        let mut names: Vec<&str> = WAL_TAGS.iter().map(|(_, n)| *n).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), WAL_TAGS.len(), "duplicate WAL tag name");
        // every TAG_* const appears in the registry
        for tag in [
            TAG_JOB,
            TAG_NODE,
            TAG_BRICK,
            TAG_RESULT,
            TAG_JOB_UPDATE,
            TAG_BRICK_UPDATE,
            TAG_BRICK_EPOCH,
        ] {
            assert!(
                WAL_TAGS.iter().any(|(b, _)| *b == tag),
                "tag byte {tag} missing from WAL_TAGS"
            );
        }
    }

    #[test]
    fn submit_and_poll() {
        let mut cat = Catalog::new();
        let (c0, ids) = cat.poll_new_jobs(0);
        assert!(ids.is_empty());
        let id = cat.submit_job(1, "max_pt > 20", "locality");
        let (c1, ids) = cat.poll_new_jobs(c0);
        assert_eq!(ids, vec![id]);
        // after the cursor advances, the same job is not re-discovered
        let (_, ids) = cat.poll_new_jobs(c1);
        assert!(ids.is_empty());
    }

    #[test]
    fn status_transitions_hide_from_poll() {
        let mut cat = Catalog::new();
        let id = cat.submit_job(1, "true", "locality");
        cat.update_job(id, |j| j.status = JobStatus::Running);
        // even from cursor 0, a Running job is not "new"
        let (_, ids) = cat.poll_new_jobs(0);
        assert!(ids.is_empty());
        assert_eq!(cat.jobs.get(id).unwrap().status, JobStatus::Running);
    }

    #[test]
    fn results_aggregate_per_job() {
        let mut cat = Catalog::new();
        let id = cat.submit_job(1, "true", "locality");
        for i in 0..3 {
            cat.record_result(ResultRow {
                job: id,
                node: format!("n{i}"),
                brick: BrickId::new(1, i),
                events_in: 100,
                events_selected: 10,
                result_bytes: 1000,
            });
        }
        assert_eq!(cat.job_results(id).len(), 3);
        assert_eq!(cat.job_results(999).len(), 0);
    }

    #[test]
    fn wal_durability() {
        let dir = std::env::temp_dir().join("geps-catalog-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("cat-{}.wal", std::process::id()));
        let _ = std::fs::remove_file(&p);

        let job_id;
        {
            let mut cat = Catalog::open(&p).unwrap();
            job_id = cat.submit_job(7, "met > 30", "proof");
            cat.register_node("gandalf", 0.8, 1);
            cat.insert_brick(
                BrickId::new(7, 0),
                500,
                500 << 20,
                vec!["gandalf".into()],
            );
            cat.update_job(job_id, |j| {
                j.status = JobStatus::Done;
                j.events_processed = 500;
            });
            cat.record_result(ResultRow {
                job: job_id,
                node: "gandalf".into(),
                brick: BrickId::new(7, 0),
                events_in: 500,
                events_selected: 42,
                result_bytes: 4200,
            });
        }
        let cat = Catalog::open(&p).unwrap();
        let job = cat.jobs.get(job_id).unwrap();
        assert_eq!(job.status, JobStatus::Done);
        assert_eq!(job.events_processed, 500);
        assert_eq!(job.filter_expr, "met > 30");
        assert_eq!(cat.nodes.len(), 1);
        assert_eq!(cat.bricks.len(), 1);
        assert_eq!(cat.results.len(), 1);
        assert_eq!(cat.bricks_for_dataset(7).len(), 1);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn holder_rewrite_replays_in_place() {
        let dir = std::env::temp_dir().join("geps-catalog-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("holders-{}.wal", std::process::id()));
        let _ = std::fs::remove_file(&p);

        let brick = BrickId::new(3, 0);
        {
            let mut cat = Catalog::open(&p).unwrap();
            cat.insert_brick(brick, 100, 1 << 20, vec!["node0".into()]);
            // two rewrites: failover then join-rebalance
            assert!(cat
                .set_brick_holders(brick, vec!["node1".into()]));
            assert!(cat.set_brick_holders(
                brick,
                vec!["node3".into(), "node1".into()]
            ));
            assert!(!cat
                .set_brick_holders(BrickId::new(9, 9), vec!["x".into()]));
        }
        let cat = Catalog::open(&p).unwrap();
        // exactly ONE row survives replay (rewrites must not duplicate)
        assert_eq!(cat.bricks.len(), 1);
        let row = cat.bricks.iter().next().map(|(_, b)| b.clone()).unwrap();
        assert_eq!(row.holders, vec!["node3", "node1"]);
        assert_eq!(row.n_events, 100, "metadata survives the rewrite");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn content_epochs_survive_replay_and_ignore_placement_churn() {
        let dir = std::env::temp_dir().join("geps-catalog-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("epochs-{}.wal", std::process::id()));
        let _ = std::fs::remove_file(&p);

        let b0 = BrickId::new(4, 0);
        let b1 = BrickId::new(4, 1);
        {
            let mut cat = Catalog::open(&p).unwrap();
            cat.insert_brick(b0, 100, 1 << 20, vec!["a".into()]);
            cat.insert_brick(b1, 100, 1 << 20, vec!["a".into()]);
            assert_eq!(cat.brick_epochs(4), vec![(b0, 1), (b1, 1)]);
            // data change on b0 only
            assert_eq!(cat.bump_content_epoch(b0), Some(2));
            assert_eq!(cat.bump_content_epoch(BrickId::new(9, 9)), None);
            // placement churn must NOT move epochs
            assert!(cat.set_brick_holders(b0, vec!["b".into()]));
            assert!(cat
                .set_brick_holders(b1, vec!["b".into(), "a".into()]));
            assert_eq!(cat.brick_epochs(4), vec![(b0, 2), (b1, 1)]);
        }
        // replay: epochs durable, exactly one row per brick
        let cat = Catalog::open(&p).unwrap();
        assert_eq!(cat.bricks.len(), 2);
        assert_eq!(cat.brick_epochs(4), vec![(b0, 2), (b1, 1)]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn job_status_names_roundtrip() {
        for s in [
            JobStatus::Submitted,
            JobStatus::Staging,
            JobStatus::Running,
            JobStatus::Merging,
            JobStatus::Done,
            JobStatus::Failed,
            JobStatus::Cancelled,
        ] {
            assert_eq!(JobStatus::by_name(s.name()), Some(s));
        }
        assert!(JobStatus::Done.is_terminal());
        assert!(JobStatus::Cancelled.is_terminal());
        assert!(!JobStatus::Running.is_terminal());
    }
}
