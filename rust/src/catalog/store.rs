//! Generic row store with secondary indexes and change versions.
//!
//! Each mutation bumps a table-wide version counter and stamps the row;
//! `changed_since(v)` is the primitive the JSE broker polls with — the
//! paper's "broker that searches from time to time into the Meta-data
//! catalogue" becomes an O(changes) scan instead of a full-table read.

use std::collections::BTreeMap;

/// Row identifier (monotonic per table).
pub type RowId = u64;

/// A typed table of rows.
#[derive(Debug, Clone)]
pub struct Table<R> {
    rows: BTreeMap<RowId, (u64, R)>, // id -> (version, row)
    next_id: RowId,
    version: u64,
}

impl<R: Clone> Default for Table<R> {
    fn default() -> Self {
        Self::new()
    }
}

impl<R: Clone> Table<R> {
    pub fn new() -> Self {
        Table { rows: BTreeMap::new(), next_id: 1, version: 0 }
    }

    /// Insert a row; returns its id.
    pub fn insert(&mut self, row: R) -> RowId {
        let id = self.next_id;
        self.next_id += 1;
        self.version += 1;
        self.rows.insert(id, (self.version, row));
        id
    }

    /// Insert with a caller-chosen id (WAL replay). Panics on collision.
    pub fn insert_with_id(&mut self, id: RowId, row: R) {
        assert!(!self.rows.contains_key(&id), "duplicate row id {id}");
        self.version += 1;
        self.rows.insert(id, (self.version, row));
        self.next_id = self.next_id.max(id + 1);
    }

    pub fn get(&self, id: RowId) -> Option<&R> {
        self.rows.get(&id).map(|(_, r)| r)
    }

    /// Update in place via closure; bumps the row's version. Returns
    /// false if the row doesn't exist.
    pub fn update(&mut self, id: RowId, f: impl FnOnce(&mut R)) -> bool {
        if let Some((v, r)) = self.rows.get_mut(&id) {
            f(r);
            self.version += 1;
            *v = self.version;
            true
        } else {
            false
        }
    }

    pub fn remove(&mut self, id: RowId) -> Option<R> {
        let out = self.rows.remove(&id).map(|(_, r)| r);
        if out.is_some() {
            self.version += 1;
        }
        out
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Current table version (the broker's cursor position).
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn iter(&self) -> impl Iterator<Item = (RowId, &R)> {
        self.rows.iter().map(|(id, (_, r))| (*id, r))
    }

    /// Rows whose version is strictly greater than `since`, oldest first.
    /// This is the broker poll primitive.
    pub fn changed_since(&self, since: u64) -> Vec<(RowId, &R)> {
        let mut out: Vec<(u64, RowId, &R)> = self
            .rows
            .iter()
            .filter(|(_, (v, _))| *v > since)
            .map(|(id, (v, r))| (*v, *id, r))
            .collect();
        out.sort_by_key(|(v, _, _)| *v);
        out.into_iter().map(|(_, id, r)| (id, r)).collect()
    }

    /// Linear scan select (the catalogue's tables are small; indexes are
    /// built by the schema layer where needed).
    pub fn select(&self, pred: impl Fn(&R) -> bool) -> Vec<(RowId, &R)> {
        self.iter().filter(|(_, r)| pred(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_update_remove() {
        let mut t: Table<String> = Table::new();
        let id = t.insert("a".into());
        assert_eq!(t.get(id), Some(&"a".to_string()));
        assert!(t.update(id, |r| r.push('b')));
        assert_eq!(t.get(id), Some(&"ab".to_string()));
        assert_eq!(t.remove(id), Some("ab".to_string()));
        assert_eq!(t.get(id), None);
        assert!(!t.update(id, |_| {}));
    }

    #[test]
    fn ids_monotonic() {
        let mut t: Table<u32> = Table::new();
        let a = t.insert(1);
        let b = t.insert(2);
        assert!(b > a);
        t.remove(b);
        let c = t.insert(3);
        assert!(c > b, "ids never reused");
    }

    #[test]
    fn changed_since_cursor() {
        let mut t: Table<u32> = Table::new();
        let a = t.insert(10);
        let v1 = t.version();
        let b = t.insert(20);
        let changed: Vec<RowId> =
            t.changed_since(v1).into_iter().map(|(id, _)| id).collect();
        assert_eq!(changed, vec![b]);
        // updating an old row re-surfaces it after the cursor
        let v2 = t.version();
        t.update(a, |r| *r += 1);
        let changed: Vec<RowId> =
            t.changed_since(v2).into_iter().map(|(id, _)| id).collect();
        assert_eq!(changed, vec![a]);
        // cursor at head sees nothing
        assert!(t.changed_since(t.version()).is_empty());
    }

    #[test]
    fn changed_since_ordered_oldest_first() {
        let mut t: Table<u32> = Table::new();
        let a = t.insert(1);
        let b = t.insert(2);
        t.update(a, |r| *r += 1); // a now newer than b
        let ids: Vec<RowId> =
            t.changed_since(0).into_iter().map(|(id, _)| id).collect();
        assert_eq!(ids, vec![b, a]);
    }

    #[test]
    fn insert_with_id_replay() {
        let mut t: Table<u32> = Table::new();
        t.insert_with_id(5, 50);
        t.insert_with_id(3, 30);
        assert_eq!(t.get(5), Some(&50));
        // next natural id continues after the max
        let id = t.insert(60);
        assert_eq!(id, 6);
    }

    #[test]
    #[should_panic]
    fn insert_with_id_collision_panics() {
        let mut t: Table<u32> = Table::new();
        t.insert_with_id(1, 1);
        t.insert_with_id(1, 2);
    }

    #[test]
    fn select_predicate() {
        let mut t: Table<u32> = Table::new();
        for i in 0..10 {
            t.insert(i);
        }
        let odd = t.select(|r| r % 2 == 1);
        assert_eq!(odd.len(), 5);
    }
}
