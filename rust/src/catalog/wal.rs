//! Write-ahead log: append-only record stream with per-record checksums,
//! giving the catalogue crash recovery (replay on open). Records are
//! opaque payload bytes tagged with a table name — the schema layer
//! encodes/decodes rows.
//!
//! Record framing: len u32 | table_tag u8 | payload | xxhash64. A torn
//! tail (partial last record / bad checksum) is truncated on replay, the
//! standard WAL recovery semantic.

use crate::util::xxhash64;
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const HASH_SEED: u64 = 0x77a1;

/// One decoded WAL record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WalRecord {
    pub tag: u8,
    pub payload: Vec<u8>,
}

/// Append-only WAL backed by a file.
pub struct Wal {
    path: PathBuf,
    file: File,
}

impl Wal {
    /// Open (creating if needed) and return the WAL plus all intact
    /// records replayed from it.
    pub fn open(path: &Path) -> std::io::Result<(Wal, Vec<WalRecord>)> {
        let mut existing = Vec::new();
        if path.exists() {
            let mut f = File::open(path)?;
            f.read_to_end(&mut existing)?;
        }
        let (records, valid_len) = Self::replay(&existing);
        // truncate torn tail if any
        if valid_len != existing.len() {
            std::fs::write(path, &existing[..valid_len])?;
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok((Wal { path: path.to_path_buf(), file }, records))
    }

    /// Decode as many intact records as possible; returns (records,
    /// valid_byte_len).
    pub fn replay(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
        let mut out = Vec::new();
        let mut i = 0usize;
        while i + 5 <= bytes.len() {
            let len =
                u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap()) as usize;
            let tag = bytes[i + 4];
            let body_start = i + 5;
            let body_end = body_start + len;
            let rec_end = body_end + 8;
            if rec_end > bytes.len() {
                break; // torn tail
            }
            let payload = &bytes[body_start..body_end];
            let sum =
                u64::from_le_bytes(bytes[body_end..rec_end].try_into().unwrap());
            if xxhash64(payload, HASH_SEED ^ tag as u64) != sum {
                break; // corruption: stop at last intact prefix
            }
            out.push(WalRecord { tag, payload: payload.to_vec() });
            i = rec_end;
        }
        (out, i)
    }

    /// Append a record and fsync.
    pub fn append(&mut self, tag: u8, payload: &[u8]) -> std::io::Result<()> {
        let mut buf =
            Vec::with_capacity(4 + 1 + payload.len() + 8);
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.push(tag);
        buf.extend_from_slice(payload);
        buf.extend_from_slice(
            &xxhash64(payload, HASH_SEED ^ tag as u64).to_le_bytes(),
        );
        self.file.write_all(&buf)?;
        self.file.sync_data()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("geps-wal-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{name}-{}.wal", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn append_and_replay() {
        let p = tmp("basic");
        {
            let (mut wal, recs) = Wal::open(&p).unwrap();
            assert!(recs.is_empty());
            wal.append(1, b"job1").unwrap();
            wal.append(2, b"node-a").unwrap();
        }
        let (_, recs) = Wal::open(&p).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0], WalRecord { tag: 1, payload: b"job1".to_vec() });
        assert_eq!(recs[1].tag, 2);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn torn_tail_truncated() {
        let p = tmp("torn");
        {
            let (mut wal, _) = Wal::open(&p).unwrap();
            wal.append(1, b"complete-record").unwrap();
            wal.append(1, b"will-be-torn").unwrap();
        }
        // chop the last 5 bytes, simulating a crash mid-write
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        let (mut wal, recs) = Wal::open(&p).unwrap();
        assert_eq!(recs.len(), 1);
        // appending after recovery works and replays cleanly
        wal.append(3, b"post-crash").unwrap();
        drop(wal);
        let (_, recs) = Wal::open(&p).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].tag, 3);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn corrupt_record_stops_replay() {
        let p = tmp("corrupt");
        {
            let (mut wal, _) = Wal::open(&p).unwrap();
            wal.append(1, b"good").unwrap();
            wal.append(1, b"bad").unwrap();
        }
        let mut bytes = std::fs::read(&p).unwrap();
        // flip a byte in the second record's payload
        let idx = bytes.len() - 9; // inside "bad" payload
        bytes[idx] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        let (_, recs) = Wal::open(&p).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].payload, b"good");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn replay_recovers_exact_record_prefix_at_every_truncation() {
        // Torn-tail property, checked exhaustively (which subsumes the
        // random-offset variant): for EVERY possible truncation point
        // of a WAL image, replay must return exactly the records whose
        // frames are fully contained in the prefix — never a phantom
        // record, never one fewer — and report the byte length of that
        // intact prefix.
        let mut buf = Vec::new();
        let mut ends = Vec::new(); // cumulative frame-end offsets
        let payloads: Vec<Vec<u8>> =
            (0..8u8).map(|i| vec![i; (i as usize * 7 + 1) % 23]).collect();
        for (i, p) in payloads.iter().enumerate() {
            let tag = (i % 3 + 1) as u8;
            buf.extend_from_slice(&(p.len() as u32).to_le_bytes());
            buf.push(tag);
            buf.extend_from_slice(p);
            buf.extend_from_slice(
                &xxhash64(p, HASH_SEED ^ tag as u64).to_le_bytes(),
            );
            ends.push(buf.len());
        }
        for cut in 0..=buf.len() {
            let (recs, valid) = Wal::replay(&buf[..cut]);
            let expect = ends.iter().filter(|e| **e <= cut).count();
            assert_eq!(recs.len(), expect, "cut at byte {cut}");
            assert_eq!(
                valid,
                if expect == 0 { 0 } else { ends[expect - 1] },
                "cut at byte {cut}"
            );
            for (r, p) in recs.iter().zip(payloads.iter()) {
                assert_eq!(&r.payload, p, "cut at byte {cut}");
            }
        }
    }

    #[test]
    fn random_truncation_of_a_real_wal_file_recovers_and_appends() {
        // The file-level variant: truncate an on-disk WAL at seeded
        // random offsets, reopen, and check the recovered prefix is
        // exact and the log accepts appends afterwards.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x7042_11);
        for trial in 0..16u64 {
            let p = tmp(&format!("randtrunc-{trial}"));
            let payloads: Vec<Vec<u8>> =
                (0..6u8).map(|i| vec![i ^ trial as u8; 5 + i as usize]).collect();
            let mut ends = Vec::new();
            {
                let (mut wal, _) = Wal::open(&p).unwrap();
                for (i, pay) in payloads.iter().enumerate() {
                    wal.append((i % 4 + 1) as u8, pay).unwrap();
                    ends.push(std::fs::metadata(&p).unwrap().len() as usize);
                }
            }
            let bytes = std::fs::read(&p).unwrap();
            let cut = rng.range_u64(0, bytes.len() as u64 + 1) as usize;
            std::fs::write(&p, &bytes[..cut]).unwrap();
            let (mut wal, recs) = Wal::open(&p).unwrap();
            let expect = ends.iter().filter(|e| **e <= cut).count();
            assert_eq!(recs.len(), expect, "trial {trial} cut {cut}");
            for (r, pay) in recs.iter().zip(payloads.iter()) {
                assert_eq!(&r.payload, pay);
            }
            // post-recovery appends land cleanly after the kept prefix
            wal.append(7, b"post-crash").unwrap();
            drop(wal);
            let (_, recs) = Wal::open(&p).unwrap();
            assert_eq!(recs.len(), expect + 1);
            assert_eq!(recs[expect].payload, b"post-crash");
            std::fs::remove_file(&p).unwrap();
        }
    }

    #[test]
    fn empty_payload_ok() {
        let p = tmp("empty");
        {
            let (mut wal, _) = Wal::open(&p).unwrap();
            wal.append(7, b"").unwrap();
        }
        let (_, recs) = Wal::open(&p).unwrap();
        assert_eq!(recs.len(), 1);
        assert!(recs[0].payload.is_empty());
        std::fs::remove_file(&p).unwrap();
    }
}
