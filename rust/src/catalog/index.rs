//! Secondary indexes over catalogue tables: maintain a key → row-id
//! multimap alongside a `Table`, so hot lookups (results by job, bricks
//! by dataset) stay O(log n + k) instead of full scans as tables grow to
//! production sizes (the paper's PgSQL gave them this for free).
//!
//! The index is maintained *explicitly* by the schema layer on insert —
//! the same discipline a database trigger would enforce — and checked
//! for consistency in tests.

use crate::catalog::store::RowId;
use std::collections::BTreeMap;

/// A multimap index from `K` to row ids.
#[derive(Debug, Clone)]
pub struct Index<K: Ord + Clone> {
    map: BTreeMap<K, Vec<RowId>>,
    entries: usize,
}

impl<K: Ord + Clone> Default for Index<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K: Ord + Clone> Index<K> {
    pub fn new() -> Self {
        Index { map: BTreeMap::new(), entries: 0 }
    }

    /// Register `id` under `key`.
    pub fn insert(&mut self, key: K, id: RowId) {
        self.map.entry(key).or_default().push(id);
        self.entries += 1;
    }

    /// Remove a specific (key, id) pair; returns whether it existed.
    pub fn remove(&mut self, key: &K, id: RowId) -> bool {
        if let Some(v) = self.map.get_mut(key) {
            if let Some(pos) = v.iter().position(|x| *x == id) {
                v.remove(pos);
                self.entries -= 1;
                if v.is_empty() {
                    self.map.remove(key);
                }
                return true;
            }
        }
        false
    }

    /// Row ids for `key` (empty slice if none).
    pub fn get(&self, key: &K) -> &[RowId] {
        self.map.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Number of (key, id) pairs.
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    /// Distinct keys, ascending.
    pub fn keys(&self) -> impl Iterator<Item = &K> {
        self.map.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove() {
        let mut ix: Index<u64> = Index::new();
        ix.insert(7, 1);
        ix.insert(7, 2);
        ix.insert(9, 3);
        assert_eq!(ix.get(&7), &[1, 2]);
        assert_eq!(ix.get(&9), &[3]);
        assert_eq!(ix.get(&8), &[] as &[RowId]);
        assert_eq!(ix.len(), 3);
        assert!(ix.remove(&7, 1));
        assert!(!ix.remove(&7, 1));
        assert_eq!(ix.get(&7), &[2]);
        assert!(ix.remove(&7, 2));
        assert!(ix.get(&7).is_empty());
        assert_eq!(ix.keys().collect::<Vec<_>>(), vec![&9]);
    }

    #[test]
    fn many_keys_ordered() {
        let mut ix: Index<String> = Index::new();
        for i in (0..100).rev() {
            ix.insert(format!("k{i:03}"), i);
        }
        let keys: Vec<&String> = ix.keys().collect();
        assert_eq!(keys.len(), 100);
        assert!(keys.windows(2).all(|w| w[0] < w[1]));
    }
}
