//! The Meta-data catalogue — the paper's PostgreSQL backend (§4.2),
//! rebuilt as an embedded typed store. It holds the four relations the
//! JSE needs (job specification tuples, node registry, brick locations,
//! results), provides secondary indexes, a write-ahead log for
//! persistence, and the **poll cursor** the JSE broker uses ("through its
//! broker that searches from time to time into the Meta-data catalogue").
//!
//! - [`store`]: generic row table: insert/get/update, secondary index,
//!   monotonically increasing row versions feeding the poll cursor
//! - [`wal`]: append-only log + replay (crash recovery)
//! - [`schema`]: the concrete GEPS relations and the [`Catalog`] facade

pub mod index;
pub mod schema;
pub mod store;
pub mod wal;

pub use schema::{BrickRow, Catalog, JobRow, JobStatus, NodeRow, ResultRow};
pub use index::Index;
pub use store::{RowId, Table};
