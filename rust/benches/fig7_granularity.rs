//! Bench: **Fig 7** — time cost vs raw-event-file size, GEPS parallel
//! (gandalf+hobbit) vs hobbit-only, plus the §6 granularity discussion
//! ("different granularities of event data will dramatically affect the
//! overall performance").
//!
//! Regenerates the paper's series on the calibrated DES. Shape targets:
//! crossover near 2000 events; GEPS gains modest (1.2–1.6×) above it and
//! growing with N; granularity sweep shows small-brick overhead.

use geps::sim::{Scenario, ScenarioConfig};
use geps::util::bench::{print_table, time_once};

fn main() {
    let groups = [
        250usize, 500, 750, 1000, 1500, 2000, 2500, 3000, 4000, 6000, 8000,
        12000, 16000,
    ];
    let reps = 10; // 13 groups x 10 reps = 130 executions, as in §6

    let mut rows = Vec::new();
    let (_, wall) = time_once(|| {
        for &n in &groups {
            let mut single = 0.0;
            let mut geps = 0.0;
            for _ in 0..reps {
                single += Scenario::run(ScenarioConfig::fig7_hobbit_only(n))
                    .makespan_s;
                geps +=
                    Scenario::run(ScenarioConfig::fig7_geps(n)).makespan_s;
            }
            single /= reps as f64;
            geps /= reps as f64;
            rows.push(vec![
                n.to_string(),
                format!("{single:.1}"),
                format!("{geps:.1}"),
                format!("{:.2}x", single / geps),
                (if geps < single { "GEPS" } else { "hobbit" }).to_string(),
            ]);
        }
    });
    print_table(
        "Fig 7: time cost (s) vs number of events (130 executions)",
        &["events", "hobbit-only", "GEPS(2 nodes)", "speedup", "winner"],
        &rows,
    );
    println!("(whole sweep simulated in {wall:.2}s wall)");

    // §6 granularity: same 4000-event file in different brick sizes,
    // prototype (staged) mode where transfer setup costs repeat per brick
    let mut rows = Vec::new();
    for epb in [50usize, 125, 250, 500, 1000, 2000] {
        let mut cfg = ScenarioConfig::fig7_geps_staged(4000);
        cfg.events_per_brick = epb;
        let r = Scenario::run(cfg);
        rows.push(vec![
            epb.to_string(),
            4000usize.div_ceil(epb).to_string(),
            format!("{:.1}", r.makespan_s),
        ]);
    }
    print_table(
        "granularity (§6): 4000 events, staged mode — smaller files pay more overhead",
        &["events/brick", "bricks", "makespan(s)"],
        &rows,
    );
}
