//! Bench: **Ext-S** — the scale/chaos scenario matrix with
//! machine-readable verdicts.
//!
//! A named matrix of scenarios, each scored into one JSON cell:
//!
//! - `sim_wan_asymmetric` — the deterministic DES (`sim::Scenario` +
//!   `netsim`) on a three-site asymmetric WAN with hundreds of
//!   simulated nodes and a straggler speed spread, run under both
//!   placement policies;
//! - `sim_stragglers_churn` — a large LAN simulation with slow nodes
//!   and staggered mid-run node kills (replication 2 must absorb them);
//! - `live_chaos_stragglers` — the live cluster under seeded
//!   stall/slow/delay faults with speculation on;
//! - `live_churn_mixed` — kill + join churn during mixed query
//!   traffic on the live cluster;
//! - `live_zipf_qcache` — zipfian filter popularity against the
//!   enabled query cache (cache-hot head, cold tail);
//! - `live_doctor_quarantine` — a mid-traffic node kill scored through
//!   the telemetry loop: the health engine must report the dead node
//!   unhealthy on `/health`, its strikes must trip the quarantine
//!   ledger, and the federated scrape's node-labeled counters must sum
//!   exactly to the cluster roll-up.
//!
//! Every cell records the same verdict shape: `ok` (terminal states
//! and invariants held), `bit_identical` (results byte-equal to the
//! fault-free baseline — or, for the DES cells, a same-config replay),
//! jobs/sec, p50/p99 job wall time, and the speculation / retry /
//! cache counters scraped from the metrics registry. Results land in
//! `BENCH_ext_scenarios.json` at the repo root; CI runs this in smoke
//! mode (`GEPS_BENCH_SMOKE=1`), uploads the JSON, and gates on every
//! cell's `ok` and `bit_identical`.
//!
//! Hermetic: kernels run on the backend `GEPS_BACKEND` selects (the
//! pure-Rust reference programs by default).

use geps::catalog::JobStatus;
use geps::cluster::ClusterHandle;
use geps::config::{ClusterConfig, NodeSpec};
use geps::faultline::FaultConfig;
use geps::netsim::{Link, Topology};
use geps::scheduler::Policy;
use geps::sim::{FailureSpec, RunReport, Scenario, ScenarioConfig};
use geps::util::bench::print_table;
use geps::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

const TIMEOUT: Duration = Duration::from_secs(120);

/// Filter pool for the live cells; the zipfian cell samples ranks from
/// the front (hot) to the back (cold).
const POOL: [&str; 6] = [
    "n_tracks >= 0",
    "met > 10",
    "met > 20",
    "n_tracks > 5",
    "max_pair_mass > 50",
    "met > 10 && n_tracks > 2",
];

/// One verdict cell of the matrix.
struct Cell {
    name: &'static str,
    kind: &'static str,
    jobs: usize,
    ok: bool,
    bit_identical: bool,
    jobs_per_sec: f64,
    p50_wall_ms: f64,
    p99_wall_ms: f64,
    counters: Vec<(&'static str, u64)>,
}

impl Cell {
    fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters = counters.set(*k, *v);
        }
        Json::obj()
            .set("name", self.name)
            .set("kind", self.kind)
            .set("jobs", self.jobs)
            .set("ok", self.ok)
            .set("bit_identical", self.bit_identical)
            .set("jobs_per_sec", self.jobs_per_sec)
            .set("p50_wall_ms", self.p50_wall_ms)
            .set("p99_wall_ms", self.p99_wall_ms)
            .set("counters", counters)
    }
}

fn pct(vals: &[f64], q: f64) -> f64 {
    if vals.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = vals.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let idx = ((v.len() as f64 - 1.0) * q).round() as usize;
    v[idx.min(v.len() - 1)]
}

// ---------------------------------------------------------------- sim cells

/// The fields a same-config DES replay must reproduce exactly.
fn sim_fingerprint(r: &RunReport) -> (u64, u64, usize, usize, bool) {
    (
        r.makespan_s.to_bits(),
        r.raw_bytes_moved,
        r.events_processed,
        r.tasks_completed,
        r.completed,
    )
}

/// Three sites behind one leader: a gigabit campus LAN, a tuned-window
/// WAN, and an untuned default-window WAN, with a straggler speed
/// spread inside every site.
fn wan_asymmetric_config(
    policy: Policy,
    per_site: usize,
    n_events: usize,
) -> ScenarioConfig {
    let mut topo = Topology::new("jse", Link::wan_default_window());
    let mut speeds = BTreeMap::new();
    let site_links =
        [Link::lan_gigabit(), Link::wan_tuned_window(), Link::wan_default_window()];
    for (s, link) in site_links.iter().enumerate() {
        for i in 0..per_site {
            let name = format!("s{s}n{i:03}");
            topo.add_host(&name);
            topo.set_link("jse", &name, *link);
            // deterministic straggler spread: 0.5×, 0.67×, 0.83×, 1.0×
            speeds.insert(name, 0.5 + 0.5 * ((i % 4) as f64) / 3.0);
        }
    }
    let mut cfg = ScenarioConfig::paper_defaults(topo, policy, n_events);
    cfg.speeds = speeds;
    cfg.events_per_brick = 100;
    cfg.replication = 2;
    cfg.raw_at_leader = false;
    cfg.stage_parallel = true; // §7 extension; serialized staging of
                               // hundreds of nodes would drown the signal
    cfg.streams = 4;
    cfg
}

fn sim_wan_asymmetric(per_site: usize, n_events: usize) -> Cell {
    let mut walls = Vec::new();
    let mut ok = true;
    let mut bit_identical = true;
    let mut tasks = 0u64;
    let mut raw_bytes = 0u64;
    for policy in [Policy::Locality, Policy::Central] {
        let a = Scenario::run(wan_asymmetric_config(policy, per_site, n_events));
        let b = Scenario::run(wan_asymmetric_config(policy, per_site, n_events));
        bit_identical &= sim_fingerprint(&a) == sim_fingerprint(&b);
        ok &= a.completed && a.events_processed == n_events && a.lost_bricks == 0;
        walls.push(a.makespan_s * 1000.0);
        tasks += a.tasks_completed as u64;
        raw_bytes += a.raw_bytes_moved;
    }
    let total_s: f64 = walls.iter().sum::<f64>() / 1000.0;
    Cell {
        name: "sim_wan_asymmetric",
        kind: "sim",
        jobs: walls.len(),
        ok,
        bit_identical,
        jobs_per_sec: walls.len() as f64 / total_s.max(1e-9),
        p50_wall_ms: pct(&walls, 0.5),
        p99_wall_ms: pct(&walls, 0.99),
        counters: vec![
            ("tasks_completed", tasks),
            ("raw_bytes_moved", raw_bytes),
            ("nodes", (3 * per_site) as u64),
        ],
    }
}

fn stragglers_churn_config(n_nodes: usize, n_events: usize) -> ScenarioConfig {
    let mut cfg = ScenarioConfig::paper_defaults(
        Topology::lan_cluster(n_nodes, Link::lan_fast_ethernet()),
        Policy::Locality,
        n_events,
    );
    for (i, w) in cfg.topology.workers().into_iter().enumerate() {
        // spread 0.25× .. 1.0× — real stragglers, deterministically placed
        cfg.speeds.insert(w, 0.25 + 0.75 * ((i % 5) as f64) / 4.0);
    }
    cfg.events_per_brick = 100;
    cfg.replication = 2;
    cfg.raw_at_leader = false;
    cfg.stage_parallel = true;
    // staggered mid-run kills; replication 2 must absorb every one
    cfg.failures = (1..=3)
        .map(|i| FailureSpec {
            node: format!("node{i}"),
            at_s: 150.0 * i as f64,
        })
        .collect();
    cfg
}

fn sim_stragglers_churn(n_nodes: usize, n_events: usize) -> Cell {
    let a = Scenario::run(stragglers_churn_config(n_nodes, n_events));
    let b = Scenario::run(stragglers_churn_config(n_nodes, n_events));
    let ok = a.completed && a.events_processed == n_events && a.lost_bricks == 0;
    let wall_ms = a.makespan_s * 1000.0;
    Cell {
        name: "sim_stragglers_churn",
        kind: "sim",
        jobs: 1,
        ok,
        bit_identical: sim_fingerprint(&a) == sim_fingerprint(&b),
        jobs_per_sec: 1.0 / a.makespan_s.max(1e-9),
        p50_wall_ms: wall_ms,
        p99_wall_ms: wall_ms,
        counters: vec![
            ("tasks_completed", a.tasks_completed as u64),
            ("tasks_failed", a.tasks_failed as u64),
            ("nodes", n_nodes as u64),
            ("nodes_killed", 3),
        ],
    }
}

// --------------------------------------------------------------- live cells

fn live_config(n_nodes: usize, n_events: usize, fault: FaultConfig) -> ClusterConfig {
    let mut cfg = ClusterConfig::default();
    cfg.nodes = (0..n_nodes)
        .map(|i| NodeSpec { name: format!("node{i}"), speed: 1.0, slots: 1 })
        .collect();
    cfg.replication = 2;
    cfg.n_events = n_events;
    cfg.events_per_brick = 100;
    cfg.time_scale = 2000.0;
    cfg.qcache_enabled = false;
    cfg.fault = fault;
    cfg
}

fn histogram_bits(cluster: &ClusterHandle, job: u64) -> Option<Vec<u32>> {
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        if let Some(h) = cluster.histogram(job) {
            return Some(h.iter().map(|v| v.to_bits()).collect());
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    None
}

/// Fault-free reference bits for every pool filter (the physics depend
/// only on the dataset, never on node count, faults, or caching).
fn baselines(n_events: usize) -> Vec<Vec<u32>> {
    let cluster = ClusterHandle::start(
        live_config(3, n_events, FaultConfig::default()),
        geps::runtime::default_artifacts_dir(),
    )
    .expect("baseline cluster");
    let out = POOL
        .iter()
        .map(|f| {
            let job = cluster.submit(f, "locality");
            assert_eq!(
                cluster.wait(job, TIMEOUT).expect("baseline job"),
                JobStatus::Done
            );
            histogram_bits(&cluster, job).expect("baseline histogram")
        })
        .collect();
    cluster.shutdown();
    out
}

fn wall_quantiles_ms(cluster: &ClusterHandle) -> (f64, f64) {
    let h = cluster.metrics.histogram("jse.job_wall_ns");
    (h.quantile(0.5) as f64 / 1e6, h.quantile(0.99) as f64 / 1e6)
}

/// Wait every submitted job out and score it against the baseline.
/// Returns (all done, all bit-identical).
fn score_jobs(
    cluster: &ClusterHandle,
    jobs: &[(u64, usize)],
    baseline: &[Vec<u32>],
) -> (bool, bool) {
    let mut all_done = true;
    let mut bit_identical = true;
    for (job, fi) in jobs {
        match cluster.wait(*job, TIMEOUT) {
            Ok(JobStatus::Done) => {
                bit_identical &= histogram_bits(cluster, *job).as_deref()
                    == Some(baseline[*fi].as_slice());
            }
            _ => all_done = false,
        }
    }
    (all_done, bit_identical)
}

fn live_chaos_stragglers(n_events: usize, baseline: &[Vec<u32>]) -> Cell {
    let fault = FaultConfig {
        seed: 91,
        stall_p: 0.3,
        stall_s: 1.0,
        slow_p: 0.3,
        slow_factor: 3.0,
        delay_p: 0.3,
        delay_factor: 4.0,
        ..FaultConfig::default()
    };
    let cluster = ClusterHandle::start(
        live_config(3, n_events, fault),
        geps::runtime::default_artifacts_dir(),
    )
    .expect("cluster start");
    let t0 = Instant::now();
    let jobs: Vec<(u64, usize)> = vec![
        (cluster.submit(POOL[0], "locality"), 0),
        (cluster.submit(POOL[1], "locality"), 1),
        (cluster.submit(POOL[0], "central"), 0),
        (cluster.submit(POOL[1], "central"), 1),
    ];
    let (ok, bit_identical) = score_jobs(&cluster, &jobs, baseline);
    let elapsed = t0.elapsed().as_secs_f64();
    let (p50, p99) = wall_quantiles_ms(&cluster);
    let injected = cluster.fault_trace().len() as u64;
    let m = &cluster.metrics;
    let counters = vec![
        ("faults_injected", injected),
        ("tasks_speculated", m.counter("jse.tasks_speculated").get()),
        ("speculation_wins", m.counter("jse.speculation_wins").get()),
        ("tasks_failed_over", m.counter("jse.tasks_failed_over").get()),
        ("gass_transfer_retries", m.counter("gass.transfer_retries").get()),
    ];
    let n = jobs.len();
    cluster.shutdown();
    Cell {
        name: "live_chaos_stragglers",
        kind: "live",
        jobs: n,
        ok: ok && injected > 0,
        bit_identical,
        jobs_per_sec: n as f64 / elapsed.max(1e-9),
        p50_wall_ms: p50,
        p99_wall_ms: p99,
        counters,
    }
}

fn live_churn_mixed(n_events: usize, baseline: &[Vec<u32>]) -> Cell {
    let cluster = ClusterHandle::start(
        live_config(4, n_events, FaultConfig::default()),
        geps::runtime::default_artifacts_dir(),
    )
    .expect("cluster start");
    let t0 = Instant::now();
    let jobs: Vec<(u64, usize)> = vec![
        (cluster.submit(POOL[0], "locality"), 0),
        (cluster.submit(POOL[1], "central"), 1),
        (cluster.submit(POOL[2], "locality"), 2),
        (cluster.submit(POOL[3], "locality"), 3),
    ];
    // kill + join churn while the traffic is in flight; replication 2
    // keeps every brick reachable, so the verdicts must not move
    std::thread::sleep(Duration::from_millis(50));
    cluster.kill_node("node3");
    cluster.add_node("node4", 1.0, 1).expect("join during traffic");
    let (ok, bit_identical) = score_jobs(&cluster, &jobs, baseline);
    let elapsed = t0.elapsed().as_secs_f64();
    let (p50, p99) = wall_quantiles_ms(&cluster);
    let m = &cluster.metrics;
    let counters = vec![
        ("nodes_joined", m.counter("cluster.nodes_joined").get()),
        ("nodes_killed", m.counter("cluster.nodes_killed").get()),
        ("tasks_failed_over", m.counter("jse.tasks_failed_over").get()),
        ("bricks_rereplicated", m.counter("ft.bricks_rereplicated").get()),
    ];
    let n = jobs.len();
    cluster.shutdown();
    Cell {
        name: "live_churn_mixed",
        kind: "live",
        jobs: n,
        ok,
        bit_identical,
        jobs_per_sec: n as f64 / elapsed.max(1e-9),
        p50_wall_ms: p50,
        p99_wall_ms: p99,
        counters,
    }
}

/// Check the federation invariant over one `/metrics` scrape: for every
/// `geps_node_*` counter family (and histogram `_count`), the
/// node-labeled samples must sum exactly to the unlabeled cluster
/// roll-up — both sides render from the same snapshot set, so any
/// drift is a merge bug, not a race. Gauges fold by max and are
/// skipped. Returns false if no node-labeled series showed up at all.
fn federation_sums_hold(text: &str) -> bool {
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    for l in text.lines() {
        if let Some(rest) = l.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            if let (Some(n), Some(t)) = (it.next(), it.next()) {
                types.insert(n.to_string(), t.to_string());
            }
        }
    }
    // family -> (roll-up total, node-labeled total, saw a node label)
    let mut sums: BTreeMap<String, (u64, u64, bool)> = BTreeMap::new();
    for l in text.lines() {
        if l.starts_with('#') {
            continue;
        }
        let Some((name_labels, value)) = l.rsplit_once(' ') else {
            continue;
        };
        let Ok(v) = value.parse::<u64>() else {
            continue;
        };
        let (name, labels) = match name_labels.split_once('{') {
            Some((n, rest)) => (n, rest.trim_end_matches('}')),
            None => (name_labels, ""),
        };
        let base = name.strip_suffix("_count").unwrap_or(name);
        let additive = match types.get(base).map(String::as_str) {
            Some("counter") => base == name,
            Some("histogram") => name.ends_with("_count"),
            _ => false,
        };
        if !additive || !base.starts_with("geps_node_") {
            continue;
        }
        let e = sums.entry(base.to_string()).or_insert((0, 0, false));
        if labels.contains("node=\"") {
            e.1 += v;
            e.2 = true;
        } else {
            e.0 += v;
        }
    }
    sums.values().any(|&(_, _, seen)| seen)
        && sums.values().all(|&(rollup, labeled, seen)| !seen || rollup == labeled)
}

/// Kill a node mid-traffic and let the telemetry feedback loop run its
/// course: the dead heartbeat turns the node's `/health` verdict
/// unhealthy, every broker telemetry tick converts that verdict into a
/// quarantine strike, and the strike threshold trips the quarantine
/// ledger — all visible to `geps doctor` through the same body this
/// cell polls.
fn live_doctor_quarantine(n_events: usize, baseline: &[Vec<u32>]) -> Cell {
    let cluster = ClusterHandle::start(
        live_config(4, n_events, FaultConfig::default()),
        geps::runtime::default_artifacts_dir(),
    )
    .expect("cluster start");
    let t0 = Instant::now();
    let jobs: Vec<(u64, usize)> = vec![
        (cluster.submit(POOL[0], "locality"), 0),
        (cluster.submit(POOL[4], "central"), 4),
    ];
    std::thread::sleep(Duration::from_millis(50));
    cluster.kill_node("node3");
    let (ok, bit_identical) = score_jobs(&cluster, &jobs, baseline);
    // the verdict and the quarantine trip land on the broker's
    // telemetry cadence; poll the doctor body until both show up
    let needle = "\"node\":\"node3\",\"verdict\":\"unhealthy\"";
    let mut doctored = false;
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        if cluster.health_json().contains(needle)
            && cluster.metrics.counter("ft.nodes_quarantined").get() > 0
        {
            doctored = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let sums_ok = federation_sums_hold(&cluster.metrics_text());
    let elapsed = t0.elapsed().as_secs_f64();
    let (p50, p99) = wall_quantiles_ms(&cluster);
    let m = &cluster.metrics;
    let counters = vec![
        ("nodes_quarantined", m.counter("ft.nodes_quarantined").get()),
        ("tasks_failed_over", m.counter("jse.tasks_failed_over").get()),
        ("doctor_unhealthy_reported", u64::from(doctored)),
        ("federation_sums_ok", u64::from(sums_ok)),
    ];
    let n = jobs.len();
    cluster.shutdown();
    Cell {
        name: "live_doctor_quarantine",
        kind: "live",
        jobs: n,
        ok: ok && doctored && sums_ok,
        bit_identical,
        jobs_per_sec: n as f64 / elapsed.max(1e-9),
        p50_wall_ms: p50,
        p99_wall_ms: p99,
        counters,
    }
}

fn live_zipf_qcache(n_events: usize, n_jobs: usize, baseline: &[Vec<u32>]) -> Cell {
    let mut cfg = live_config(3, n_events, FaultConfig::default());
    cfg.qcache_enabled = true;
    let cluster =
        ClusterHandle::start(cfg, geps::runtime::default_artifacts_dir())
            .expect("cluster start");
    // zipf(1) over the pool via a seeded LCG: rank r gets weight 1/(r+1)
    let weights: Vec<f64> = (0..POOL.len()).map(|r| 1.0 / (r + 1) as f64).collect();
    let total: f64 = weights.iter().sum();
    let mut state: u64 = 0x5eed_cafe_f00d_beef;
    let mut rank = || {
        state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let mut x = (state >> 11) as f64 / (1u64 << 53) as f64 * total;
        for (r, w) in weights.iter().enumerate() {
            if x < *w {
                return r;
            }
            x -= w;
        }
        POOL.len() - 1
    };
    let t0 = Instant::now();
    let jobs: Vec<(u64, usize)> = (0..n_jobs)
        .map(|_| {
            let r = rank();
            (cluster.submit(POOL[r], "locality"), r)
        })
        .collect();
    let (ok, bit_identical) = score_jobs(&cluster, &jobs, baseline);
    let elapsed = t0.elapsed().as_secs_f64();
    let (p50, p99) = wall_quantiles_ms(&cluster);
    let m = &cluster.metrics;
    let counters = vec![
        ("qcache_hits_full", m.counter("qcache.hits_full").get()),
        ("qcache_hits_partial", m.counter("qcache.hits_partial").get()),
        ("qcache_shared_jobs", m.counter("qcache.shared_jobs").get()),
        ("qcache_promotions", m.counter("qcache.promotions").get()),
    ];
    let hits = counters[0].1 + counters[1].1 + counters[2].1;
    cluster.shutdown();
    Cell {
        name: "live_zipf_qcache",
        kind: "live",
        jobs: n_jobs,
        // the hot head must actually hit the cache for the cell to count
        ok: ok && hits > 0,
        bit_identical,
        jobs_per_sec: n_jobs as f64 / elapsed.max(1e-9),
        p50_wall_ms: p50,
        p99_wall_ms: p99,
        counters,
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("GEPS_BENCH_SMOKE").is_ok();
    let n_events = if smoke { 400 } else { 1000 };
    // DES scale: hundreds of simulated nodes in the full run
    let per_site = if smoke { 20 } else { 100 };
    let churn_nodes = if smoke { 40 } else { 200 };
    let sim_events = if smoke { 12_000 } else { 60_000 };
    let zipf_jobs = if smoke { 16 } else { 40 };

    let baseline = baselines(n_events);
    let cells = vec![
        sim_wan_asymmetric(per_site, sim_events),
        sim_stragglers_churn(churn_nodes, sim_events),
        live_chaos_stragglers(n_events, &baseline),
        live_churn_mixed(n_events, &baseline),
        live_zipf_qcache(n_events, zipf_jobs, &baseline),
        live_doctor_quarantine(n_events, &baseline),
    ];

    print_table(
        "Ext-S scenarios: scale/chaos matrix verdicts",
        &["cell", "kind", "jobs", "ok", "bit-identical", "jobs/s", "p50", "p99"],
        &cells
            .iter()
            .map(|c| {
                vec![
                    c.name.to_string(),
                    c.kind.to_string(),
                    c.jobs.to_string(),
                    c.ok.to_string(),
                    c.bit_identical.to_string(),
                    format!("{:.2}", c.jobs_per_sec),
                    format!("{:.1} ms", c.p50_wall_ms),
                    format!("{:.1} ms", c.p99_wall_ms),
                ]
            })
            .collect::<Vec<_>>(),
    );
    let all_ok = cells.iter().all(|c| c.ok);
    let all_bit_identical = cells.iter().all(|c| c.bit_identical);
    println!("\nall ok: {all_ok}, all bit-identical: {all_bit_identical}");

    let doc = Json::obj()
        .set("bench", "ext_scenarios")
        .set("generated", true)
        .set("smoke", smoke)
        .set(
            "config",
            Json::obj()
                .set("n_events_live", n_events)
                .set("n_events_sim", sim_events)
                .set("sim_nodes_wan", 3 * per_site)
                .set("sim_nodes_churn", churn_nodes)
                .set("zipf_jobs", zipf_jobs),
        )
        .set("cells", cells.iter().map(Cell::to_json).collect::<Vec<_>>())
        .set("all_ok", all_ok)
        .set("all_bit_identical", all_bit_identical);

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_ext_scenarios.json");
    std::fs::write(&path, format!("{doc}\n"))?;
    println!("wrote {}", path.display());
    Ok(())
}
