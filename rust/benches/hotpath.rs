//! Bench: L3 hot paths — the coordinator must never be the bottleneck
//! (DESIGN.md §Perf targets): scheduler decisions, catalogue ops, wire
//! codec, filter evaluation, brick encode/decode, DES event rate,
//! histogram merge. Used by the §Perf optimization loop; before/after
//! numbers live in EXPERIMENTS.md.

use geps::brick::{codec, BrickFile, BrickId, Codec};
use geps::catalog::Catalog;
use geps::events::{EventBatch, EventGenerator, GeneratorConfig};
use geps::filterexpr;
use geps::scheduler::{BrickState, NodeState, Policy, SchedCtx};
use geps::sim::Engine as SimEngine;
use geps::util::bench::{bench, print_table};
use geps::wire::Message;

fn sched_ctx(nodes: usize, bricks: usize) -> SchedCtx {
    SchedCtx {
        nodes: (0..nodes)
            .map(|i| NodeState {
                name: format!("node{i}"),
                speed: 1.0,
                slots: 1,
                up: true,
            })
            .collect(),
        bricks: (0..bricks)
            .map(|i| BrickState {
                id: BrickId::new(1, i as u32),
                n_events: 500,
                bytes: 500 << 20,
                holders: vec![format!("node{}", i % nodes)],
            })
            .collect(),
        leader: "jse".into(),
    }
}

fn main() {
    let mut rows = Vec::new();
    let mut push = |name: &str, unit: &str, per_iter: f64, s: geps::util::bench::Stats| {
        rows.push(vec![
            name.to_string(),
            format!("{:.2} us", s.mean_ns / 1e3),
            format!("{:.0} {unit}/s", s.throughput(per_iter)),
        ]);
    };

    // scheduler: full drain of 1024 bricks over 16 nodes
    let ctx = sched_ctx(16, 1024);
    let s = bench(3, 30, || {
        let mut sched = Policy::Locality.build(&ctx);
        let mut n = 0;
        loop {
            let mut any = false;
            for node in 0..16 {
                if let Some(t) =
                    sched.next_task(&format!("node{node}"), &ctx)
                {
                    sched.on_complete(&format!("node{node}"), &t, 1.0);
                    n += 1;
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        assert_eq!(n, 1024);
    });
    push("scheduler drain (locality, 1024 tasks)", "decisions", 1024.0, s);

    let s = bench(3, 30, || {
        let mut sched = Policy::Proof.build(&ctx);
        let mut n = 0;
        while !sched.is_done() {
            for node in 0..16 {
                if let Some(t) =
                    sched.next_task(&format!("node{node}"), &ctx)
                {
                    sched.on_complete(
                        &format!("node{node}"),
                        &t,
                        t.n_events() as f64 / 1000.0,
                    );
                    n += 1;
                }
            }
        }
        std::hint::black_box(n);
    });
    push("scheduler drain (proof packets)", "packets", 1.0, s);

    // catalogue: submit+poll+update cycle
    let s = bench(3, 50, || {
        let mut cat = Catalog::new();
        let mut cursor = 0;
        for i in 0..200 {
            let id = cat.submit_job(1, "met > 1", "locality");
            let (c, jobs) = cat.poll_new_jobs(cursor);
            cursor = c;
            assert_eq!(jobs.len(), 1);
            cat.update_job(id, |j| {
                j.status = geps::catalog::JobStatus::Done;
                j.events_processed = i;
            });
        }
    });
    push("catalog submit+poll+update x200", "ops", 600.0, s);

    // wire codec round-trip
    let msg = Message::TaskDone {
        job: 7,
        brick: BrickId::new(2, 9),
        range: (0, 512),
        events_in: 512,
        events_selected: 48,
        result_bytes: 4800,
        histogram: vec![0u8; 8 * 64 * 4],
    };
    let s = bench(100, 5000, || {
        let enc = msg.encode();
        let (dec, _) = Message::decode(&enc).unwrap();
        std::hint::black_box(dec);
    });
    push("wire codec TaskDone round-trip (2KB hist)", "msgs", 1.0, s);

    // filter expression over a feature matrix
    let filter = filterexpr::compile(
        "max_pair_mass > 80 && max_pair_mass < 100 && max_pt > 20 || met > 50",
    )
    .unwrap();
    let feats: Vec<f32> = (0..256 * 8).map(|i| (i % 97) as f32).collect();
    let s = bench(100, 5000, || {
        std::hint::black_box(filter.accept_batch(&feats, 256).len());
    });
    push("filter eval, 256-event batch", "events", 256.0, s);

    // brick encode/decode (LZSS) of 500 events
    let events = EventGenerator::new(GeneratorConfig::default(), 7).take(500);
    let s = bench(3, 100, || {
        let b = BrickFile::encode(BrickId::new(1, 0), &events, Codec::Lzss, 128);
        let (_, dec) = BrickFile::decode(&b.bytes).unwrap();
        assert_eq!(dec.len(), 500);
    });
    push("brick encode+decode 500 events (LZSS)", "events", 500.0, s);

    // raw LZSS on a 1 MB event-like payload
    let brick = BrickFile::encode(BrickId::new(1, 0), &events, Codec::Raw, 500);
    let payload = &brick.bytes;
    let s = bench(3, 50, || {
        let c = codec::compress(payload);
        std::hint::black_box(codec::decompress(&c, payload.len()).unwrap());
    });
    push(
        "LZSS compress+decompress brick payload",
        "MB",
        payload.len() as f64 / 1e6,
        s,
    );

    // batch packing (node executor inner loop)
    let s = bench(10, 500, || {
        std::hint::black_box(EventBatch::pack(&events, 256, 32));
    });
    push("EventBatch::pack 256x32", "events", 500.0, s);

    // DES engine raw event rate
    let s = bench(3, 30, || {
        struct W {
            n: u64,
        }
        fn tick(e: &mut SimEngine<W>, w: &mut W) {
            w.n += 1;
            if w.n < 100_000 {
                e.schedule(0.001, tick);
            }
        }
        let mut eng = SimEngine::new();
        let mut w = W { n: 0 };
        eng.schedule(0.001, tick);
        eng.run(&mut w);
        assert_eq!(w.n, 100_000);
    });
    push("DES engine 100k events", "sim-events", 100_000.0, s);

    // histogram merge
    let mut acc: Vec<f32> = vec![0.0; 8 * 64];
    let raw: Vec<u8> = (0..8 * 64)
        .flat_map(|_| 1.0f32.to_le_bytes())
        .collect();
    let s = bench(100, 5000, || {
        geps::jse::merge_histogram(&mut acc, &raw);
    });
    push("histogram merge (8x64 bins)", "merges", 1.0, s);

    print_table(
        "L3 hot paths",
        &["path", "mean latency", "throughput"],
        &rows,
    );
}
