//! Bench: L3 hot paths — the coordinator must never be the bottleneck
//! (DESIGN.md §Perf targets): scheduler decisions, catalogue ops, wire
//! codec, filter evaluation, brick encode/decode, DES event rate,
//! histogram merge — plus the columnar-vs-row node hot path (v2 bricks
//! + filter bytecode vs v1 bricks + tree walk), and the **full engine
//! path**: decode → pack → kernel (features) → filter → histogram
//! through the backend-dispatched [`geps::runtime::Engine`]. The engine
//! stages are hermetic too — auto backend selection provisions the
//! pure-Rust reference programs when no native XLA artifacts are
//! present — so the JSON carries real end-to-end numbers in any
//! checkout.
//!
//! Besides the human-readable table, writes machine-readable results to
//! `BENCH_hotpath.json` at the repo root so the perf trajectory is
//! tracked across PRs (CI runs this in smoke mode — set
//! `GEPS_BENCH_SMOKE=1` for a fast pass — and uploads the JSON as a
//! workflow artifact).

use geps::brick::{codec, BrickFile, BrickId, Codec, ColumnarEvents};
use geps::catalog::Catalog;
use geps::events::{
    EventBatch, EventGenerator, GeneratorConfig, NUM_FEATURES,
};
use geps::filterexpr;
use geps::runtime::{Engine, EnginePool, FeatureMatrix};
use geps::scheduler::{BrickState, NodeState, Policy, SchedCtx};
use geps::sim::Engine as SimEngine;
use geps::util::bench::{bench, print_table, Stats};
use geps::util::json::Json;
use geps::wire::Message;
use std::collections::VecDeque;
use std::sync::mpsc::Receiver;

fn sched_ctx(nodes: usize, bricks: usize) -> SchedCtx {
    SchedCtx {
        nodes: (0..nodes)
            .map(|i| NodeState {
                name: format!("node{i}"),
                speed: 1.0,
                slots: 1,
                up: true,
            })
            .collect(),
        bricks: (0..bricks)
            .map(|i| BrickState {
                id: BrickId::new(1, i as u32),
                n_events: 500,
                bytes: 500 << 20,
                holders: vec![format!("node{}", i % nodes)],
            })
            .collect(),
        leader: "jse".into(),
    }
}

/// The node hot-path configuration the columnar comparison runs at.
const HOT_EVENTS: usize = 2000;
const HOT_EPP: usize = 256; // events per brick page
const HOT_BATCH: usize = 256;
const HOT_TRACKS: usize = 32;
const HOT_FILTER: &str =
    "max_pair_mass > 80 && max_pair_mass < 100 && max_pt > 20 || met > 50";

fn main() {
    let smoke = std::env::var("GEPS_BENCH_SMOKE").is_ok();
    // smoke mode: same benches, fewer iterations (CI wants signal that
    // the path works and a rough number, not tight confidence intervals)
    let scale = |iters: usize| if smoke { (iters / 10).max(5) } else { iters };

    let mut rows = Vec::new();
    // (key, events/sec from the mean, median ns per iteration)
    let mut results: Vec<(String, f64, f64)> = Vec::new();
    let mut push = |name: &str,
                    key: Option<&str>,
                    unit: &str,
                    per_iter: f64,
                    s: Stats| {
        let tput = s.throughput(per_iter);
        rows.push(vec![
            name.to_string(),
            format!("{:.2} us", s.mean_ns / 1e3),
            format!("{tput:.0} {unit}/s"),
        ]);
        if let Some(k) = key {
            results.push((k.to_string(), tput, s.p50_ns));
        }
    };

    // scheduler: full drain of 1024 bricks over 16 nodes
    let ctx = sched_ctx(16, 1024);
    let s = bench(3, scale(30), || {
        let mut sched = Policy::Locality.build(&ctx);
        let mut n = 0;
        loop {
            let mut any = false;
            for node in 0..16 {
                if let Some(t) =
                    sched.next_task(&format!("node{node}"), &ctx)
                {
                    sched.on_complete(&format!("node{node}"), &t, 1.0);
                    n += 1;
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        assert_eq!(n, 1024);
    });
    push("scheduler drain (locality, 1024 tasks)", None, "decisions", 1024.0, s);

    let s = bench(3, scale(30), || {
        let mut sched = Policy::Proof.build(&ctx);
        let mut n = 0;
        while !sched.is_done() {
            for node in 0..16 {
                if let Some(t) =
                    sched.next_task(&format!("node{node}"), &ctx)
                {
                    sched.on_complete(
                        &format!("node{node}"),
                        &t,
                        t.n_events() as f64 / 1000.0,
                    );
                    n += 1;
                }
            }
        }
        std::hint::black_box(n);
    });
    push("scheduler drain (proof packets)", None, "packets", 1.0, s);

    // catalogue: submit+poll+update cycle
    let s = bench(3, scale(50), || {
        let mut cat = Catalog::new();
        let mut cursor = 0;
        for i in 0..200 {
            let id = cat.submit_job(1, "met > 1", "locality");
            let (c, jobs) = cat.poll_new_jobs(cursor);
            cursor = c;
            assert_eq!(jobs.len(), 1);
            cat.update_job(id, |j| {
                j.status = geps::catalog::JobStatus::Done;
                j.events_processed = i;
            });
        }
    });
    push("catalog submit+poll+update x200", None, "ops", 600.0, s);

    // wire codec round-trip
    let msg = Message::TaskDone {
        job: 7,
        brick: BrickId::new(2, 9),
        range: (0, 512),
        attempt: 0,
        events_in: 512,
        events_selected: 48,
        result_bytes: 4800,
        histogram: vec![0u8; 8 * 64 * 4],
    };
    let s = bench(100, scale(5000), || {
        let enc = msg.encode();
        let (dec, _) = Message::decode(&enc).unwrap();
        std::hint::black_box(dec);
    });
    push("wire codec TaskDone round-trip (2KB hist)", None, "msgs", 1.0, s);

    // ---- the node hot path: columnar v2 vs row-wise v1 ----------------
    let events =
        EventGenerator::new(GeneratorConfig::default(), 7).take(HOT_EVENTS);
    let cols = ColumnarEvents::from_events(&events);
    let v1 =
        BrickFile::encode(BrickId::new(1, 0), &events, Codec::Lzss, HOT_EPP);
    let v2 = BrickFile::encode_columnar(
        BrickId::new(1, 0),
        &cols,
        Codec::Lzss,
        HOT_EPP,
    );
    let filter = filterexpr::compile(HOT_FILTER).unwrap();
    // one batch worth of synthetic kernel output, reused per page
    let feats: Vec<f32> =
        (0..HOT_BATCH * NUM_FEATURES).map(|i| (i % 97) as f32).collect();

    // decode: v1 rows vs v2 columns
    let s = bench(3, scale(60), || {
        let (_, dec) = BrickFile::decode(&v1.bytes).unwrap();
        assert_eq!(dec.len(), HOT_EVENTS);
    });
    push(
        "brick decode v1 rows (LZSS, 2000 ev)",
        Some("decode_v1_rowwise"),
        "events",
        HOT_EVENTS as f64,
        s,
    );
    let s = bench(3, scale(60), || {
        let (_, dec) = BrickFile::decode_columnar(&v2.bytes).unwrap();
        assert_eq!(dec.len(), HOT_EVENTS);
    });
    push(
        "brick decode v2 columnar (LZSS, 2000 ev)",
        Some("decode_v2_columnar"),
        "events",
        HOT_EVENTS as f64,
        s,
    );

    // batch packing: row structs vs column slices
    let s = bench(10, scale(500), || {
        for chunk in events.chunks(HOT_BATCH) {
            std::hint::black_box(EventBatch::pack(
                chunk, HOT_BATCH, HOT_TRACKS,
            ));
        }
    });
    push(
        "EventBatch::pack rows 2000 ev",
        Some("pack_rowwise"),
        "events",
        HOT_EVENTS as f64,
        s,
    );
    let s = bench(10, scale(500), || {
        let mut start = 0;
        while start < cols.len() {
            let end = (start + HOT_BATCH).min(cols.len());
            std::hint::black_box(cols.pack_range(
                (start, end),
                HOT_BATCH,
                HOT_TRACKS,
            ));
            start = end;
        }
    });
    push(
        "pack_range columns 2000 ev",
        Some("pack_columnar"),
        "events",
        HOT_EVENTS as f64,
        s,
    );

    // filter: recursive tree walk vs scalar bytecode vs SIMD bitmask VM
    let s = bench(100, scale(5000), || {
        std::hint::black_box(
            filter.accept_batch_treewalk(&feats, HOT_BATCH).len(),
        );
    });
    push(
        "filter tree-walk, 256-event batch",
        Some("filter_treewalk"),
        "events",
        HOT_BATCH as f64,
        s,
    );
    let mut scratch = filterexpr::VmScratch::new();
    let mut mask = Vec::new();
    let s = bench(100, scale(5000), || {
        filter.accept_batch_into_scalar(
            &feats,
            HOT_BATCH,
            &mut scratch,
            &mut mask,
        );
        std::hint::black_box(mask.len());
    });
    push(
        "filter scalar bytecode, 256-event batch",
        Some("filter_bytecode"),
        "events",
        HOT_BATCH as f64,
        s,
    );
    let mut scratch = filterexpr::VmScratch::new();
    let mut bits: Vec<u64> = Vec::new();
    let s = bench(100, scale(5000), || {
        filter.accept_batch_bits_into(
            &feats,
            HOT_BATCH,
            &mut scratch,
            &mut bits,
        );
        std::hint::black_box(bits.len());
    });
    push(
        "filter SIMD bitmask VM, 256-event batch",
        Some("filter_simd"),
        "events",
        HOT_BATCH as f64,
        s,
    );

    // end-to-end decode→pack→filter node path, old vs new
    let s = bench(3, scale(40), || {
        let (_, evs) = BrickFile::decode(&v1.bytes).unwrap();
        let mut accepted = 0usize;
        for chunk in evs.chunks(HOT_BATCH) {
            let batch = EventBatch::pack(chunk, HOT_BATCH, HOT_TRACKS);
            let m = filter.accept_batch_treewalk(&feats, batch.n_real());
            accepted += m.iter().filter(|&&k| k).count();
        }
        std::hint::black_box(accepted);
    });
    push(
        "end-to-end v1: decode+pack+tree-walk",
        Some("end_to_end_v1_row_treewalk"),
        "events",
        HOT_EVENTS as f64,
        s,
    );
    let mut scratch = filterexpr::VmScratch::new();
    let mut mask = Vec::new();
    let s = bench(3, scale(40), || {
        let (_, c) = BrickFile::decode_columnar(&v2.bytes).unwrap();
        let mut accepted = 0usize;
        let mut start = 0;
        while start < c.len() {
            let end = (start + HOT_BATCH).min(c.len());
            let batch = c.pack_range((start, end), HOT_BATCH, HOT_TRACKS);
            filter.accept_batch_into(
                &feats,
                batch.n_real(),
                &mut scratch,
                &mut mask,
            );
            accepted += mask.iter().filter(|&&k| k).count();
            start = end;
        }
        std::hint::black_box(accepted);
    });
    push(
        "end-to-end v2: decode+pack+bytecode",
        Some("end_to_end_v2_columnar_bytecode"),
        "events",
        HOT_EVENTS as f64,
        s,
    );

    // ---- the full engine path (backend-dispatched compute) ------------
    // decode → pack → kernel → filter → histogram, exactly the node
    // executor's task loop. Loads hermetically: the reference backend
    // self-provisions when no XLA artifacts are linked.
    let engine = Engine::load(&geps::runtime::default_artifacts_dir())
        .expect("engine loads hermetically (reference backend)");
    let backend = engine.backend_name();
    assert_eq!(
        (engine.manifest.batch, engine.manifest.max_tracks),
        (HOT_BATCH, HOT_TRACKS),
        "the engine stages are calibrated for the model.py default \
         shapes; point GEPS_ARTIFACTS away from the non-default \
         artifacts dir (or regenerate it with `geps gen-artifacts`) \
         before benching"
    );
    let calib = Engine::identity_calib();

    // kernel alone over all pages
    let s = bench(3, scale(20), || {
        let mut start = 0;
        while start < cols.len() {
            let end = (start + HOT_BATCH).min(cols.len());
            let batch =
                cols.pack_range((start, end), HOT_BATCH, HOT_TRACKS);
            std::hint::black_box(engine.features(&batch, &calib).unwrap());
            start = end;
        }
    });
    push(
        &format!("engine features kernel 2000 ev ({backend})"),
        Some("engine_features"),
        "events",
        HOT_EVENTS as f64,
        s,
    );

    // single-threaded end-to-end through the engine
    let mut scratch = filterexpr::VmScratch::new();
    let mut mask = Vec::new();
    let s = bench(3, scale(20), || {
        let (_, c) = BrickFile::decode_columnar(&v2.bytes).unwrap();
        let mut hist: Vec<f32> = Vec::new();
        let mut accepted = 0usize;
        let mut start = 0;
        while start < c.len() {
            let end = (start + HOT_BATCH).min(c.len());
            let batch = c.pack_range((start, end), HOT_BATCH, HOT_TRACKS);
            let feats = engine.features(&batch, &calib).unwrap();
            filter.accept_batch_into(
                &feats.data,
                feats.n_real,
                &mut scratch,
                &mut mask,
            );
            let mut sel = vec![0f32; HOT_BATCH];
            for (i, &keep) in mask.iter().enumerate() {
                if keep {
                    sel[i] = 1.0;
                    accepted += 1;
                }
            }
            let h = engine.histogram(&feats, &sel).unwrap();
            merge_into(&mut hist, h);
            start = end;
        }
        std::hint::black_box((accepted, hist.len()));
    });
    push(
        &format!("engine end-to-end 2000 ev ({backend})"),
        Some("engine_end_to_end"),
        "events",
        HOT_EVENTS as f64,
        s,
    );

    // pipelined through the engine pool (the executor's shape: one
    // kernel execution in flight while the next page packs)
    let pool =
        EnginePool::start(geps::runtime::default_artifacts_dir(), 2)
            .expect("pool starts hermetically");
    let mut scratch = filterexpr::VmScratch::new();
    let mut mask = Vec::new();
    let s = bench(3, scale(20), || {
        let (_, c) = BrickFile::decode_columnar(&v2.bytes).unwrap();
        let mut hist: Vec<f32> = Vec::new();
        let mut accepted = 0usize;
        let mut inflight: VecDeque<Receiver<anyhow::Result<FeatureMatrix>>> =
            VecDeque::new();
        let mut start = 0;
        while start < c.len() {
            let end = (start + HOT_BATCH).min(c.len());
            let batch = c.pack_range((start, end), HOT_BATCH, HOT_TRACKS);
            inflight.push_back(pool.features_async(batch, calib).unwrap());
            if inflight.len() >= 2 {
                accepted += drain_one_bench(
                    &mut inflight,
                    &pool,
                    &filter,
                    &mut scratch,
                    &mut mask,
                    &mut hist,
                );
            }
            start = end;
        }
        while !inflight.is_empty() {
            accepted += drain_one_bench(
                &mut inflight,
                &pool,
                &filter,
                &mut scratch,
                &mut mask,
                &mut hist,
            );
        }
        std::hint::black_box((accepted, hist.len()));
    });
    push(
        &format!("engine pipelined (pool x2) 2000 ev ({backend})"),
        Some("engine_pipelined"),
        "events",
        HOT_EVENTS as f64,
        s,
    );
    pool.shutdown();

    // multi-pipeline executor shape: N workers steal pages from a shared
    // cursor, each with one kernel in flight, drained strictly in page
    // order — exactly what `node/executor.rs` runs per task
    let pipelines = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .max(2);
    let mpool = EnginePool::start(
        geps::runtime::default_artifacts_dir(),
        pipelines,
    )
    .expect("pool starts hermetically");
    let s = bench(3, scale(20), || {
        let (accepted, hist) =
            multipipeline_pass(&v2.bytes, &mpool, &filter, calib, pipelines);
        std::hint::black_box((accepted, hist.len()));
    });
    push(
        &format!(
            "engine multi-pipeline (x{pipelines}) 2000 ev ({backend})"
        ),
        Some("engine_multipipeline"),
        "events",
        HOT_EVENTS as f64,
        s,
    );

    // bit-identity checks backing the JSON claims: v1 and v2 bricks must
    // produce identical kernel batches, all three filter evaluators must
    // produce identical accept masks, and the multi-pipeline merge must
    // reproduce the sequential histogram bit for bit
    let (_, rows_v1) = BrickFile::decode(&v1.bytes).unwrap();
    let (_, cols_v2) = BrickFile::decode_columnar(&v2.bytes).unwrap();
    let mut batches_identical = true;
    let mut start = 0;
    for chunk in rows_v1.chunks(HOT_BATCH) {
        let end = start + chunk.len();
        let a = EventBatch::pack(chunk, HOT_BATCH, HOT_TRACKS);
        let b = cols_v2.pack_range((start, end), HOT_BATCH, HOT_TRACKS);
        batches_identical &= a == b;
        start = end;
    }
    let vec_mask = filter.accept_batch(&feats, HOT_BATCH);
    let masks_identical =
        vec_mask == filter.accept_batch_treewalk(&feats, HOT_BATCH);
    let simd_masks_identical = {
        let mut scr = filterexpr::VmScratch::new();
        let mut scalar = Vec::new();
        filter.accept_batch_into_scalar(
            &feats,
            HOT_BATCH,
            &mut scr,
            &mut scalar,
        );
        let mut bits: Vec<u64> = Vec::new();
        filter.accept_batch_bits_into(&feats, HOT_BATCH, &mut scr, &mut bits);
        let expanded: Vec<bool> = (0..HOT_BATCH)
            .map(|i| bits[i / 64] >> (i % 64) & 1 == 1)
            .collect();
        vec_mask == scalar && vec_mask == expanded
    };
    let (seq_accepted, seq_hist) =
        sequential_pass(&v2.bytes, &engine, &filter, calib);
    let (mp_accepted, mp_hist) =
        multipipeline_pass(&v2.bytes, &mpool, &filter, calib, pipelines);
    let mp_hist_identical = seq_accepted == mp_accepted
        && seq_hist.len() == mp_hist.len()
        && seq_hist
            .iter()
            .zip(&mp_hist)
            .all(|(a, b)| a.to_bits() == b.to_bits());
    mpool.shutdown();
    assert!(batches_identical, "v1 and v2 kernel batches diverged");
    assert!(masks_identical, "bytecode and tree-walk masks diverged");
    assert!(simd_masks_identical, "SIMD/scalar/tree-walk masks diverged");
    assert!(
        mp_hist_identical,
        "multi-pipeline histogram diverged from the sequential merge"
    );

    // brick encode/decode (LZSS) of 500 events
    let ev500 = &events[..500];
    let s = bench(3, scale(100), || {
        let b = BrickFile::encode(BrickId::new(1, 0), ev500, Codec::Lzss, 128);
        let (_, dec) = BrickFile::decode(&b.bytes).unwrap();
        assert_eq!(dec.len(), 500);
    });
    push("brick encode+decode 500 events (LZSS)", None, "events", 500.0, s);

    // raw LZSS on a 1 MB event-like payload
    let brick = BrickFile::encode(BrickId::new(1, 0), ev500, Codec::Raw, 500);
    let payload = &brick.bytes;
    let s = bench(3, scale(50), || {
        let c = codec::compress(payload);
        std::hint::black_box(codec::decompress(&c, payload.len()).unwrap());
    });
    push(
        "LZSS compress+decompress brick payload",
        None,
        "MB",
        payload.len() as f64 / 1e6,
        s,
    );

    // DES engine raw event rate
    let s = bench(3, scale(30), || {
        struct W {
            n: u64,
        }
        fn tick(e: &mut SimEngine<W>, w: &mut W) {
            w.n += 1;
            if w.n < 100_000 {
                e.schedule(0.001, tick);
            }
        }
        let mut eng = SimEngine::new();
        let mut w = W { n: 0 };
        eng.schedule(0.001, tick);
        eng.run(&mut w);
        assert_eq!(w.n, 100_000);
    });
    push("DES engine 100k events", None, "sim-events", 100_000.0, s);

    // histogram merge
    let mut acc: Vec<f32> = vec![0.0; 8 * 64];
    let raw: Vec<u8> = (0..8 * 64)
        .flat_map(|_| 1.0f32.to_le_bytes())
        .collect();
    let s = bench(100, scale(5000), || {
        geps::jse::merge_histogram(&mut acc, &raw);
    });
    push("histogram merge (8x64 bins)", None, "merges", 1.0, s);

    print_table(
        "L3 hot paths",
        &["path", "mean latency", "throughput"],
        &rows,
    );

    write_json(
        smoke,
        backend,
        &results,
        pipelines,
        BitIdentity {
            v1_v2_kernel_batches: batches_identical,
            treewalk_bytecode_masks: masks_identical,
            simd_scalar_treewalk_masks: simd_masks_identical,
            multipipeline_histogram: mp_hist_identical,
        },
    );
}

/// One sequential decode→pack→kernel→filter→histogram pass over the v2
/// brick — the baseline the multi-pipeline merge must reproduce bit for
/// bit.
fn sequential_pass(
    bytes: &[u8],
    engine: &Engine,
    filter: &filterexpr::CompiledFilter,
    calib: [f32; 16],
) -> (usize, Vec<f32>) {
    let (_, c) = BrickFile::decode_columnar(bytes).unwrap();
    let mut scratch = filterexpr::VmScratch::new();
    let mut mask = Vec::new();
    let mut hist: Vec<f32> = Vec::new();
    let mut accepted = 0usize;
    let mut start = 0;
    while start < c.len() {
        let end = (start + HOT_BATCH).min(c.len());
        let batch = c.pack_range((start, end), HOT_BATCH, HOT_TRACKS);
        let feats = engine.features(&batch, &calib).unwrap();
        filter.accept_batch_into(
            &feats.data,
            feats.n_real,
            &mut scratch,
            &mut mask,
        );
        let mut sel = vec![0f32; HOT_BATCH];
        for (i, &keep) in mask.iter().enumerate() {
            if keep {
                sel[i] = 1.0;
                accepted += 1;
            }
        }
        let h = engine.histogram(&feats, &sel).unwrap();
        merge_into(&mut hist, h);
        start = end;
    }
    (accepted, hist)
}

/// One multi-pipeline pass over the v2 brick: `pipelines` scoped workers
/// steal page indices from a shared cursor (one kernel in flight each);
/// a strict-ordered drain merges histograms in exact page order — the
/// bench-local mirror of the node executor's task loop.
fn multipipeline_pass(
    bytes: &[u8],
    pool: &EnginePool,
    filter: &filterexpr::CompiledFilter,
    calib: [f32; 16],
    pipelines: usize,
) -> (usize, Vec<f32>) {
    let (_, c) = BrickFile::decode_columnar(bytes).unwrap();
    let n = c.len();
    let n_pages = n.div_ceil(HOT_BATCH);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let (tx, rx) = std::sync::mpsc::channel::<(usize, usize, Vec<f32>)>();
    let mut accepted = 0usize;
    let mut hist: Vec<f32> = Vec::new();
    std::thread::scope(|s| {
        let next = &next;
        let c = &c;
        for _ in 0..pipelines {
            let tx = tx.clone();
            s.spawn(move || {
                let mut scratch = filterexpr::VmScratch::new();
                let mut bits: Vec<u64> = Vec::new();
                let mut pending: Option<(
                    usize,
                    Receiver<anyhow::Result<FeatureMatrix>>,
                )> = None;
                loop {
                    let p = next
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if p >= n_pages {
                        break;
                    }
                    let start = p * HOT_BATCH;
                    let end = (start + HOT_BATCH).min(n);
                    let batch =
                        c.pack_range((start, end), HOT_BATCH, HOT_TRACKS);
                    let rxf = pool.features_async(batch, calib).unwrap();
                    if let Some((prev, prx)) = pending.replace((p, rxf)) {
                        let (a, h) =
                            finish_page(prx, pool, filter, &mut scratch, &mut bits);
                        tx.send((prev, a, h)).unwrap();
                    }
                }
                if let Some((prev, prx)) = pending.take() {
                    let (a, h) =
                        finish_page(prx, pool, filter, &mut scratch, &mut bits);
                    tx.send((prev, a, h)).unwrap();
                }
            });
        }
        drop(tx);
        let mut buffer: std::collections::BTreeMap<usize, (usize, Vec<f32>)> =
            std::collections::BTreeMap::new();
        for expect in 0..n_pages {
            let (a, h) = loop {
                if let Some(page) = buffer.remove(&expect) {
                    break page;
                }
                let (idx, a, h) = rx.recv().expect("pipeline alive");
                if idx == expect {
                    break (a, h);
                }
                buffer.insert(idx, (a, h));
            };
            accepted += a;
            merge_into(&mut hist, h);
        }
    });
    (accepted, hist)
}

/// Complete one in-flight page on a bench pipeline: bitmask filter +
/// histogram. Returns (accepted count, page histogram).
fn finish_page(
    rx: Receiver<anyhow::Result<FeatureMatrix>>,
    pool: &EnginePool,
    filter: &filterexpr::CompiledFilter,
    scratch: &mut filterexpr::VmScratch,
    bits: &mut Vec<u64>,
) -> (usize, Vec<f32>) {
    let feats = rx.recv().expect("engine worker alive").unwrap();
    filter.accept_batch_bits_into(&feats.data, feats.n_real, scratch, bits);
    let mut sel = vec![0f32; feats.batch];
    let mut accepted = 0usize;
    for (w, &word) in bits.iter().enumerate() {
        let mut m = word;
        while m != 0 {
            let i = w * 64 + m.trailing_zeros() as usize;
            sel[i] = 1.0;
            accepted += 1;
            m &= m - 1;
        }
    }
    let h = pool.histogram(feats, sel).expect("histogram");
    (accepted, h)
}

/// The bit-identity verdicts recorded in the JSON (CI gates on these).
struct BitIdentity {
    v1_v2_kernel_batches: bool,
    treewalk_bytecode_masks: bool,
    simd_scalar_treewalk_masks: bool,
    multipipeline_histogram: bool,
}

/// Elementwise histogram merge into an accumulator (first merge adopts).
fn merge_into(hist: &mut Vec<f32>, h: Vec<f32>) {
    if hist.is_empty() {
        *hist = h;
    } else {
        for (a, b) in hist.iter_mut().zip(h) {
            *a += b;
        }
    }
}

/// Complete the oldest in-flight kernel execution — the bench-local
/// mirror of the node executor's pipeline drain. Returns the number of
/// accepted events in the drained batch.
fn drain_one_bench(
    inflight: &mut VecDeque<Receiver<anyhow::Result<FeatureMatrix>>>,
    pool: &EnginePool,
    filter: &filterexpr::CompiledFilter,
    scratch: &mut filterexpr::VmScratch,
    mask: &mut Vec<bool>,
    hist: &mut Vec<f32>,
) -> usize {
    let rx = inflight.pop_front().expect("inflight non-empty");
    let feats = rx.recv().expect("engine worker alive").unwrap();
    filter.accept_batch_into(&feats.data, feats.n_real, scratch, mask);
    let mut sel = vec![0f32; feats.batch];
    let mut accepted = 0usize;
    for (i, &keep) in mask.iter().enumerate() {
        if keep {
            sel[i] = 1.0;
            accepted += 1;
        }
    }
    let h = pool.histogram(feats, sel).expect("histogram");
    merge_into(hist, h);
    accepted
}

/// Emit `BENCH_hotpath.json` at the repo root: events/sec per stage,
/// columnar-vs-row speedups, the full-engine-path numbers (with which
/// backend executed them), and the bit-identity checks.
fn write_json(
    smoke: bool,
    backend: &str,
    results: &[(String, f64, f64)],
    pipelines: usize,
    identity: BitIdentity,
) {
    // speedups compare MEDIAN iteration times (robust against a single
    // noisy-neighbor spike in smoke mode, where iteration counts are low)
    let p50 = |k: &str| {
        results
            .iter()
            .find(|(n, _, _)| n == k)
            .map(|(_, _, p)| *p)
            .unwrap_or(0.0)
    };
    let ratio = |new: &str, old: &str| {
        let (n, o) = (p50(new), p50(old));
        if n > 0.0 {
            o / n // same work per iteration, so time ratio = speedup
        } else {
            0.0
        }
    };

    let mut eps = Json::obj();
    for (k, v, _) in results {
        eps = eps.set(k, *v);
    }
    let doc = Json::obj()
        .set("bench", "hotpath")
        .set("generated", true)
        .set("smoke", smoke)
        .set(
            "config",
            Json::obj()
                .set("events", HOT_EVENTS)
                .set("events_per_page", HOT_EPP)
                .set("batch", HOT_BATCH)
                .set("max_tracks", HOT_TRACKS)
                .set("codec", "lzss")
                .set("filter", HOT_FILTER),
        )
        .set("events_per_sec", eps)
        .set(
            "speedup",
            Json::obj()
                .set("decode", ratio("decode_v2_columnar", "decode_v1_rowwise"))
                .set("pack", ratio("pack_columnar", "pack_rowwise"))
                .set("filter", ratio("filter_bytecode", "filter_treewalk"))
                .set(
                    "end_to_end",
                    ratio(
                        "end_to_end_v2_columnar_bytecode",
                        "end_to_end_v1_row_treewalk",
                    ),
                )
                .set(
                    "filter_simd",
                    ratio("filter_simd", "filter_bytecode"),
                )
                .set(
                    "engine_pipelining",
                    ratio("engine_pipelined", "engine_end_to_end"),
                )
                .set(
                    "engine_multipipeline",
                    ratio("engine_multipipeline", "engine_end_to_end"),
                ),
        )
        .set(
            "engine",
            Json::obj()
                .set("backend", backend)
                .set("batch", HOT_BATCH)
                .set("pool_workers", 2)
                .set("node_pipelines", pipelines),
        )
        .set(
            "bit_identical",
            Json::obj()
                .set("v1_v2_kernel_batches", identity.v1_v2_kernel_batches)
                .set(
                    "treewalk_bytecode_masks",
                    identity.treewalk_bytecode_masks,
                )
                .set(
                    "simd_scalar_treewalk_masks",
                    identity.simd_scalar_treewalk_masks,
                )
                .set(
                    "multipipeline_histogram",
                    identity.multipipeline_histogram,
                ),
        );

    // repo root = parent of the crate dir (rust/)
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_hotpath.json");
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
