//! Bench: **Ext-A** — the paper's §7 GridFTP plan: "multiple TCP streams
//! and proper TCP buffer sizes are very important to obtaining better
//! performance in TCP wide area links" (ref [12]).
//!
//! Sweeps parallel streams × link type for a 100 MB transfer and for a
//! whole GEPS job over a WAN-separated site. Shape targets (from [12]):
//! near-linear stream scaling on the window-starved WAN until the raw
//! path saturates; negligible gain on the LAN; a tuned window matching
//! multi-stream performance.

use geps::netsim::{transfer_time, Link, Topology, TransferSpec};
use geps::scheduler::Policy;
use geps::sim::{Scenario, ScenarioConfig};
use geps::util::bench::print_table;
use geps::util::ByteSize;

fn main() {
    let links: [(&str, Link); 4] = [
        ("LAN 100Mb/s", Link::lan_fast_ethernet()),
        ("LAN 1Gb/s", Link::lan_gigabit()),
        ("WAN 64KiB win", Link::wan_default_window()),
        ("WAN tuned win", Link::wan_tuned_window()),
    ];
    let mut rows = Vec::new();
    for (name, link) in &links {
        let base = transfer_time(
            link,
            &TransferSpec { bytes: ByteSize::mb(100), streams: 1 },
        );
        let mut row = vec![name.to_string(), format!("{base:.1}s")];
        for streams in [2u32, 4, 8, 16] {
            let t = transfer_time(
                link,
                &TransferSpec { bytes: ByteSize::mb(100), streams },
            );
            row.push(format!("{:.2}x", base / t));
        }
        rows.push(row);
    }
    print_table(
        "Ext-A: 100 MB transfer — speedup vs parallel TCP streams",
        &["link", "1 stream", "2", "4", "8", "16"],
        &rows,
    );

    // whole-job effect: a GEPS site split across a WAN (the §3 concern),
    // central staging from the far side
    let mut rows = Vec::new();
    for streams in [1u32, 2, 4, 8, 16] {
        let mut topo = Topology::lan_cluster(2, Link::lan_fast_ethernet());
        topo.set_link("jse", "node0", Link::wan_default_window());
        topo.set_link("jse", "node1", Link::wan_default_window());
        let mut cfg =
            ScenarioConfig::paper_defaults(topo, Policy::Central, 2000);
        cfg.streams = streams;
        let r = Scenario::run(cfg);
        rows.push(vec![
            streams.to_string(),
            format!("{:.0}", r.makespan_s),
            format!("{:.1} GB", r.raw_bytes_moved as f64 / 1e9),
        ]);
    }
    print_table(
        "Ext-A: whole job, central staging across a WAN (2000 events)",
        &["streams", "makespan(s)", "raw moved"],
        &rows,
    );
}
