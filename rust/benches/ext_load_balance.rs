//! Bench: **Ext-C** — §7 "develop a storage mechanism to submit more
//! work to the best nodes — load balancing".
//!
//! Heterogeneous clusters (mixed CPU speeds): compare strict locality
//! (work pinned to data holders), the `balanced` policy (cost-aware
//! migration), and PROOF-style adaptive packets. Shape targets: locality
//! is hostage to its slowest loaded node; balanced recovers most of the
//! gap when transfers pay for themselves; proof adapts packet sizes and
//! lands near balanced at fine granularity.

use geps::netsim::{Link, Topology};
use geps::scheduler::Policy;
use geps::sim::{Scenario, ScenarioConfig};
use geps::util::bench::print_table;
use geps::util::ByteSize;

fn run(policy: Policy, speeds: &[f64], n_events: usize) -> (f64, u64, f64) {
    let mut cfg = ScenarioConfig::paper_defaults(
        Topology::lan_cluster(speeds.len(), Link::lan_fast_ethernet()),
        policy,
        n_events,
    );
    cfg.events_per_brick = 250;
    cfg.raw_at_leader = false;
    for (i, s) in speeds.iter().enumerate() {
        cfg.speeds.insert(format!("node{i}"), *s);
    }
    let r = Scenario::run(cfg);
    (r.makespan_s, r.raw_bytes_moved, r.utilization())
}

fn main() {
    let mixes: [(&str, Vec<f64>); 3] = [
        ("uniform 1.0", vec![1.0; 8]),
        ("half-slow", vec![1.0, 1.0, 1.0, 1.0, 0.5, 0.5, 0.5, 0.5]),
        (
            "long-tail",
            vec![2.0, 2.0, 1.0, 1.0, 0.5, 0.5, 0.25, 0.25],
        ),
    ];
    for (name, speeds) in &mixes {
        let mut rows = Vec::new();
        for policy in [Policy::Locality, Policy::Balanced, Policy::Proof] {
            let (makespan, moved, util) = run(policy, speeds, 16_000);
            rows.push(vec![
                policy.name().to_string(),
                format!("{makespan:.0}"),
                ByteSize(moved).to_string(),
                format!("{:.0}%", util * 100.0),
            ]);
        }
        print_table(
            &format!("Ext-C: 8 nodes ({name}), 16k events"),
            &["policy", "makespan(s)", "raw moved", "util"],
            &rows,
        );
    }
}
