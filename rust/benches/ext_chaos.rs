//! Bench: **Ext-C** — the faultline chaos matrix as a measured verdict
//! run. A seeded scenario matrix (each fault class alone, then
//! combined, under multi-job traffic and node churn) flows through the
//! live cluster, and every job is scored against the faultline
//! contract:
//!
//! - sealed `Done` with a histogram bit-identical to the fault-free
//!   baseline, or
//! - sealed `Failed` with a typed, non-empty catalogue error, and
//! - terminal within the timeout — a hang is a scored failure, never a
//!   stuck bench.
//!
//! The same seed is replayed once more to score trace determinism
//! (identical injected-fault traces and identical verdicts). Results
//! land in `BENCH_ext_chaos.json` at the repo root; CI runs this in
//! smoke mode (`GEPS_BENCH_SMOKE=1`), uploads the JSON, and gates on
//! the verdict booleans.
//!
//! Hermetic: kernels run on the backend `GEPS_BACKEND` selects (the
//! pure-Rust reference programs by default).

use geps::catalog::JobStatus;
use geps::cluster::ClusterHandle;
use geps::config::{ClusterConfig, NodeSpec};
use geps::faultline::FaultConfig;
use geps::util::bench::print_table;
use std::time::{Duration, Instant};

const FILTERS: [&str; 2] = ["n_tracks >= 0", "met > 10"];
const TIMEOUT: Duration = Duration::from_secs(120);

fn chaos_config(n_events: usize, fault: FaultConfig) -> ClusterConfig {
    let mut cfg = ClusterConfig::default();
    cfg.nodes = vec![
        NodeSpec { name: "node0".into(), speed: 1.0, slots: 1 },
        NodeSpec { name: "node1".into(), speed: 1.0, slots: 1 },
        NodeSpec { name: "node2".into(), speed: 1.0, slots: 1 },
    ];
    cfg.replication = 2;
    cfg.n_events = n_events;
    cfg.events_per_brick = 100;
    cfg.time_scale = 2000.0;
    cfg.qcache_enabled = false;
    cfg.fault = fault;
    cfg
}

fn histogram_bits(cluster: &ClusterHandle, job: u64) -> Option<Vec<u32>> {
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        if let Some(h) = cluster.histogram(job) {
            return Some(h.iter().map(|v| v.to_bits()).collect());
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    None
}

/// Per-scenario score sheet.
struct Score {
    name: &'static str,
    jobs: usize,
    done: usize,
    failed_typed: usize,
    hangs: usize,
    bit_mismatches: usize,
    untyped_failures: usize,
    injected: usize,
    wall_s: f64,
}

impl Score {
    fn ok(&self) -> bool {
        self.hangs == 0
            && self.bit_mismatches == 0
            && self.untyped_failures == 0
            && self.done + self.failed_typed == self.jobs
    }
}

/// Run one scenario: submit the job mix, optionally churn a node, and
/// score every job against the contract. Returns the score plus the
/// (status, histogram-bits) verdict list for determinism replays.
#[allow(clippy::type_complexity)]
fn run_scenario(
    name: &'static str,
    n_events: usize,
    fault: FaultConfig,
    baseline: &[Vec<u32>],
    churn: bool,
) -> (Score, Vec<(String, Option<Vec<u32>>)>) {
    let cluster = ClusterHandle::start(
        chaos_config(n_events, fault),
        geps::runtime::default_artifacts_dir(),
    )
    .expect("cluster start");
    let jobs: Vec<(u64, usize)> = vec![
        (cluster.submit(FILTERS[0], "locality"), 0),
        (cluster.submit(FILTERS[1], "central"), 1),
    ];
    if churn {
        std::thread::sleep(Duration::from_millis(50));
        cluster.kill_node("node2");
    }
    let mut score = Score {
        name,
        jobs: jobs.len(),
        done: 0,
        failed_typed: 0,
        hangs: 0,
        bit_mismatches: 0,
        untyped_failures: 0,
        injected: 0,
        wall_s: 0.0,
    };
    let mut verdicts = Vec::new();
    let t0 = Instant::now();
    for (job, fi) in jobs {
        match cluster.wait(job, TIMEOUT) {
            Ok(JobStatus::Done) => {
                let bits = histogram_bits(&cluster, job);
                if bits.as_deref() == Some(baseline[fi].as_slice()) {
                    score.done += 1;
                } else {
                    score.bit_mismatches += 1;
                }
                verdicts.push(("done".to_string(), bits));
            }
            Ok(JobStatus::Failed) => {
                let err = cluster
                    .catalog
                    .lock()
                    .unwrap()
                    .jobs
                    .get(job)
                    .and_then(|j| j.error.clone());
                if err.map(|e| !e.is_empty()).unwrap_or(false) {
                    score.failed_typed += 1;
                } else {
                    score.untyped_failures += 1;
                }
                verdicts.push(("failed".to_string(), None));
            }
            Ok(other) => {
                // cancelled/queued can't happen here; score as untyped
                score.untyped_failures += 1;
                verdicts.push((format!("{other:?}"), None));
            }
            Err(_) => {
                score.hangs += 1;
                verdicts.push(("hang".to_string(), None));
            }
        }
    }
    score.wall_s = t0.elapsed().as_secs_f64();
    score.injected = cluster.fault_trace().len();
    cluster.shutdown();
    (score, verdicts)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("GEPS_BENCH_SMOKE").is_ok();
    let n_events = if smoke { 400 } else { 1000 };
    let n_bricks = n_events.div_ceil(100);

    // fault-free baseline histograms, one per filter
    let baseline: Vec<Vec<u32>> = {
        let cluster = ClusterHandle::start(
            chaos_config(n_events, FaultConfig::default()),
            geps::runtime::default_artifacts_dir(),
        )?;
        let out = FILTERS
            .iter()
            .map(|f| {
                let job = cluster.submit(f, "locality");
                assert_eq!(
                    cluster.wait(job, TIMEOUT).expect("baseline"),
                    JobStatus::Done
                );
                histogram_bits(&cluster, job).expect("baseline histogram")
            })
            .collect();
        cluster.shutdown();
        out
    };

    let single = |name: &'static str, f: FaultConfig| (name, f, false);
    let mut scenarios: Vec<(&'static str, FaultConfig, bool)> = vec![
        single(
            "stall+slow",
            FaultConfig {
                seed: 21,
                stall_p: 0.4,
                stall_s: 1.0,
                slow_p: 0.4,
                slow_factor: 2.0,
                ..FaultConfig::default()
            },
        ),
        single(
            "drop+corrupt",
            FaultConfig {
                seed: 22,
                drop_p: 0.2,
                corrupt_p: 0.2,
                ..FaultConfig::default()
            },
        ),
        single(
            "crash",
            FaultConfig { seed: 23, crash_p: 0.3, ..FaultConfig::default() },
        ),
        (
            "combined+churn",
            FaultConfig {
                seed: 24,
                drop_p: 0.1,
                dup_p: 0.2,
                delay_p: 0.2,
                corrupt_p: 0.1,
                stall_p: 0.2,
                stall_s: 1.0,
                slow_p: 0.2,
                slow_factor: 2.0,
                crash_p: 0.05,
                ..FaultConfig::default()
            },
            true,
        ),
    ];
    if !smoke {
        scenarios.extend([
            single(
                "delay",
                FaultConfig {
                    seed: 25,
                    delay_p: 0.5,
                    delay_factor: 4.0,
                    ..FaultConfig::default()
                },
            ),
            single(
                "dup",
                FaultConfig { seed: 26, dup_p: 0.5, ..FaultConfig::default() },
            ),
            single(
                "partition",
                FaultConfig {
                    seed: 27,
                    partition_p: 0.3,
                    ..FaultConfig::default()
                },
            ),
        ]);
    }

    let mut scores = Vec::new();
    for (name, fault, churn) in &scenarios {
        let (score, _) =
            run_scenario(name, n_events, fault.clone(), &baseline, *churn);
        scores.push(score);
    }

    // determinism replay: the delay-only classes query the fault plan
    // on a timing-independent key set, so two same-seed runs must
    // produce identical traces and verdicts
    let det_fault = FaultConfig {
        seed: 31,
        stall_p: 0.5,
        stall_s: 1.0,
        slow_p: 0.5,
        slow_factor: 2.0,
        speculate: false,
        ..FaultConfig::default()
    };
    let det = |f: &FaultConfig| {
        let cluster = ClusterHandle::start(
            chaos_config(n_events, f.clone()),
            geps::runtime::default_artifacts_dir(),
        )
        .expect("cluster start");
        let mut verdicts = Vec::new();
        for filter in FILTERS {
            let job = cluster.submit(filter, "locality");
            let status = cluster.wait(job, TIMEOUT);
            verdicts.push((
                format!("{status:?}"),
                histogram_bits(&cluster, job),
            ));
        }
        let trace = cluster.fault_trace();
        cluster.shutdown();
        (trace, verdicts)
    };
    let (trace_a, verdicts_a) = det(&det_fault);
    let (trace_b, verdicts_b) = det(&det_fault);
    let trace_deterministic = !trace_a.is_empty()
        && trace_a == trace_b
        && verdicts_a == verdicts_b;

    let no_hangs = scores.iter().all(|s| s.hangs == 0);
    let all_bit_identical = scores.iter().all(|s| s.bit_mismatches == 0);
    let all_failures_typed =
        scores.iter().all(|s| s.untyped_failures == 0);
    let all_scenarios_ok = scores.iter().all(Score::ok);

    print_table(
        "Ext-C chaos: seeded fault matrix verdicts",
        &["scenario", "done", "failed(typed)", "hangs", "injected", "wall"],
        &scores
            .iter()
            .map(|s| {
                vec![
                    s.name.to_string(),
                    format!("{}/{}", s.done, s.jobs),
                    s.failed_typed.to_string(),
                    s.hangs.to_string(),
                    s.injected.to_string(),
                    format!("{:.2} s", s.wall_s),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!(
        "\nno hangs: {no_hangs}, bit-identical: {all_bit_identical}, \
         typed failures: {all_failures_typed}, trace deterministic: \
         {trace_deterministic}"
    );

    let mut scen_json = Vec::new();
    for s in &scores {
        scen_json.push(
            geps::util::json::Json::obj()
                .set("name", s.name)
                .set("jobs", s.jobs)
                .set("done", s.done)
                .set("failed_typed", s.failed_typed)
                .set("hangs", s.hangs)
                .set("bit_mismatches", s.bit_mismatches)
                .set("untyped_failures", s.untyped_failures)
                .set("injected", s.injected)
                .set("wall_s", s.wall_s)
                .set("ok", s.ok()),
        );
    }
    let doc = geps::util::json::Json::obj()
        .set("bench", "ext_chaos")
        .set("generated", true)
        .set("smoke", smoke)
        .set(
            "config",
            geps::util::json::Json::obj()
                .set("n_events", n_events)
                .set("bricks", n_bricks)
                .set("scenarios", scores.len())
                .set("jobs_per_scenario", FILTERS.len()),
        )
        .set("scenarios", scen_json)
        .set("no_hangs", no_hangs)
        .set("all_bit_identical", all_bit_identical)
        .set("all_failures_typed", all_failures_typed)
        .set("trace_deterministic", trace_deterministic)
        .set("all_scenarios_ok", all_scenarios_ok);

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_ext_chaos.json");
    std::fs::write(&path, format!("{doc}\n"))?;
    println!("wrote {}", path.display());
    Ok(())
}
