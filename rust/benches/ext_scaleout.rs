//! Bench: **Ext-G** — elastic scale-out. The paper's headline claim is
//! that capacity grows by "just adding more Grid nodes"; this measures
//! it live. A 3-node grid runs a 6-job batch (baseline jobs/sec), then
//! a 4th node joins THROUGH THE MEMBERSHIP PATH (`add_node`: executor
//! spawned, catalogue + GRIS registration, brick rebalancing onto the
//! newcomer) and the same batch runs again. With locality scheduling
//! the moved bricks pull work onto the new node, so jobs/sec must
//! rise. Requires `make artifacts`.

use geps::cluster::ClusterHandle;
use geps::config::{ClusterConfig, NodeSpec};
use geps::util::bench::print_table;
use geps::util::json::Json;
use std::time::{Duration, Instant};

const JOBS: usize = 6;

const FILTERS: [&str; 3] = [
    "max_pair_mass > 80 && max_pair_mass < 100",
    "met > 10",
    "n_tracks >= 4",
];

fn run_batch(cluster: &ClusterHandle) -> anyhow::Result<f64> {
    let t0 = Instant::now();
    let jobs: Vec<u64> = (0..JOBS)
        .map(|i| cluster.submit(FILTERS[i % FILTERS.len()], "locality"))
        .collect();
    for job in &jobs {
        cluster.wait(*job, Duration::from_secs(300))?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let cat = cluster.catalog.lock().unwrap();
    for job in &jobs {
        let j = cat.jobs.get(*job).unwrap();
        assert_eq!(j.events_processed, 1200, "job {job} incomplete: {j:?}");
    }
    Ok(wall)
}

fn main() -> anyhow::Result<()> {
    let mut cfg = ClusterConfig::default();
    cfg.nodes = (0..3)
        .map(|i| NodeSpec {
            name: format!("node{i}"),
            speed: 1.0,
            slots: 1,
        })
        .collect();
    cfg.replication = 2;
    cfg.n_events = 1200;
    cfg.events_per_brick = 100;
    cfg.time_scale = 2000.0;
    cfg.max_concurrent_jobs = 4;
    // the batch repeats filters; this bench measures raw recompute
    // scale-out, so qcache full-result reuse must not short-circuit it
    // (the cache lever has its own bench, ext_qcache)
    cfg.qcache_enabled = false;
    // every node executor (including the live-joined one) runs this
    // many pipelines per task (the `[node] pipelines` knob, auto here)
    let pipelines = cfg.effective_pipelines();
    let cluster = ClusterHandle::start(
        cfg,
        geps::runtime::default_artifacts_dir(),
    )?;

    // baseline: the static 3-node grid
    let wall_before = run_batch(&cluster)?;

    // live join + rebalance, then wait until the newcomer owns bricks
    let t_join = Instant::now();
    cluster.add_node("node3", 1.0, 1)?;
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let owned = {
            let cat = cluster.catalog.lock().unwrap();
            cat.bricks
                .iter()
                .filter(|(_, b)| {
                    b.holders.first().map(String::as_str) == Some("node3")
                })
                .count()
        };
        if owned >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "rebalance never happened");
        std::thread::sleep(Duration::from_millis(10));
    }
    let join_s = t_join.elapsed().as_secs_f64();

    // the same batch on the grown grid
    let wall_after = run_batch(&cluster)?;

    let rebalanced =
        cluster.metrics.counter("ft.bricks_rebalanced").get();
    cluster.shutdown();

    print_table(
        "Ext-G: 6-job batch before/after a live node join (1200-event jobs)",
        &["grid", "wall(s)", "jobs/s"],
        &[
            vec![
                "3 nodes (static)".into(),
                format!("{wall_before:.2}"),
                format!("{:.2}", JOBS as f64 / wall_before),
            ],
            vec![
                "4 nodes (joined live)".into(),
                format!("{wall_after:.2}"),
                format!("{:.2}", JOBS as f64 / wall_after),
            ],
        ],
    );
    println!(
        "join-to-rebalanced latency: {join_s:.2}s; bricks moved: {rebalanced}"
    );
    // the acceptance bar: the joined node adds real throughput
    assert!(
        wall_after < wall_before,
        "scale-out regressed: {wall_after:.2}s (4 nodes) vs \
         {wall_before:.2}s (3 nodes)"
    );
    println!(
        "scale-out speedup: {:.2}x from one joined node",
        wall_before / wall_after
    );

    let doc = Json::obj()
        .set("bench", "ext_scaleout")
        .set("generated", true)
        .set("jobs", JOBS)
        .set("node_pipelines", pipelines)
        .set(
            "before",
            Json::obj()
                .set("nodes", 3)
                .set("wall_s", wall_before)
                .set("jobs_per_sec", JOBS as f64 / wall_before),
        )
        .set(
            "after",
            Json::obj()
                .set("nodes", 4)
                .set("wall_s", wall_after)
                .set("jobs_per_sec", JOBS as f64 / wall_after),
        )
        .set("join_to_rebalanced_s", join_s)
        .set("bricks_rebalanced", rebalanced)
        .set("speedup", wall_before / wall_after);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_ext_scaleout.json");
    std::fs::write(&path, format!("{doc}\n"))?;
    println!("wrote {}", path.display());
    Ok(())
}
