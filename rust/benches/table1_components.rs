//! Bench: **Table 1** — the Globus components GEPS uses (GRAM: executable
//! staging; GRIS/MDS: node information queries; GASS: raw-data + result
//! transfer). The paper's table is an inventory; this bench exercises
//! each component's analogue end-to-end and reports operation latencies
//! and throughput, so the inventory is backed by measurements.

use geps::brick::{BrickFile, BrickId, Codec};
use geps::events::{EventGenerator, GeneratorConfig};
use geps::gass::GassService;
use geps::gris::{parse_filter, Directory, NodeInfoProvider};
use geps::netsim::Topology;
use geps::rsl;
use geps::scheduler::Task;
use geps::util::bench::{bench, print_table};

fn main() {
    let mut rows = Vec::new();

    // --- GRAM analogue: RSL synthesis + parse (the per-task submit path)
    let task = Task {
        brick: BrickId::new(1, 3),
        range: (0, 500),
        source: Some("gandalf".into()),
    };
    let s = bench(100, 2000, || {
        let spec = rsl::synthesize_task_rsl(
            42,
            &task,
            "max_pair_mass > 80 && max_pt > 20",
            "hobbit",
            4,
        );
        let text = spec.to_string();
        let parsed = rsl::parse(&text).unwrap();
        std::hint::black_box(rsl::synth::parse_task_rsl(&parsed));
    });
    rows.push(vec![
        "GRAM".into(),
        "RSL synth+print+parse".into(),
        format!("{:.1} us", s.mean_ns / 1e3),
        format!("{:.0}/s", s.throughput(1.0)),
    ]);

    // --- GRIS/MDS analogue: LDAP query against a 64-node directory
    let mut dir = Directory::new();
    for i in 0..64 {
        NodeInfoProvider {
            name: format!("node{i}"),
            cpus: 1 + i % 4,
            speed: 1.0,
            mbps: 100,
            free_slots: i % 2,
            bricks: (0..8).map(|b| (format!("d1.b{b}"), 500)).collect(),
            up: true,
        }
        .publish(&mut dir, "geps");
    }
    let filter = parse_filter("(&(cpus>=2)(freeslots>=1)(mbps>=100))").unwrap();
    let s = bench(100, 2000, || {
        std::hint::black_box(dir.search("o=geps", &filter).len());
    });
    rows.push(vec![
        "GRIS/MDS".into(),
        format!("LDAP search, {} entries", dir.len()),
        format!("{:.1} us", s.mean_ns / 1e3),
        format!("{:.0}/s", s.throughput(1.0)),
    ]);

    // --- GASS analogue: raw-data staging + result retrieval (real bytes,
    //     netsim-timed; time_scale very high so we measure the code path)
    let gass = GassService::new(Topology::paper_testbed(), 1e9, 1);
    let events = EventGenerator::new(GeneratorConfig::default(), 7).take(500);
    let brick = BrickFile::encode(BrickId::new(1, 0), &events, Codec::Lzss, 128);
    let bytes = brick.size();
    gass.store("jse").unwrap().put("/bricks/d1.b0.brick", brick.bytes);
    let s = bench(20, 300, || {
        std::hint::black_box(
            gass.transfer("jse", "gandalf", "/bricks/d1.b0.brick").unwrap(),
        );
    });
    rows.push(vec![
        "GASS".into(),
        format!("stage 500-event brick ({bytes} B)"),
        format!("{:.1} us", s.mean_ns / 1e3),
        format!(
            "{:.0} MB/s in-proc",
            s.throughput(bytes as f64) / 1e6
        ),
    ]);
    // modelled wire cost for the same transfer (what the DES charges)
    let modelled =
        gass.cost("jse", "gandalf", bytes as u64, 1);
    rows.push(vec![
        "GASS".into(),
        "same transfer, modelled fast-Ethernet".into(),
        format!("{:.1} ms virtual", modelled * 1e3),
        format!("{:.1} MB/s wire", bytes as f64 / modelled / 1e6),
    ]);

    print_table(
        "Table 1: Globus components in GEPS — measured analogues",
        &["component", "operation", "latency", "throughput"],
        &rows,
    );
}
