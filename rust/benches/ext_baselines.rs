//! Bench: **Ext-D** — GEPS vs the related-work baselines it discusses:
//! the traditional central-server grid (§3), Gfarm fragment affinity
//! (§2) and PROOF master/worker packets (§2), across cluster sizes.
//!
//! Shape targets: central flattens early (leader NIC saturation: its
//! makespan is ~constant in node count); grid-brick locality and gfarm
//! track each other (both data-local); proof pays remote reads for
//! non-holders but adapts to stragglers; everything data-local beats
//! central by a growing factor.

use geps::netsim::{Link, Topology};
use geps::scheduler::Policy;
use geps::sim::{Scenario, ScenarioConfig};
use geps::util::bench::print_table;
use geps::util::ByteSize;

fn main() {
    let mut rows = Vec::new();
    for &nodes in &[2usize, 4, 8, 16] {
        for policy in Policy::ALL {
            let mut cfg = ScenarioConfig::paper_defaults(
                Topology::lan_cluster(nodes, Link::lan_fast_ethernet()),
                policy,
                16_000,
            );
            cfg.events_per_brick = 500;
            cfg.raw_at_leader = false;
            cfg.stage_parallel = true; // isolate the data-movement effect
            let r = Scenario::run(cfg);
            rows.push(vec![
                nodes.to_string(),
                policy.name().to_string(),
                format!("{:.0}", r.makespan_s),
                ByteSize(r.raw_bytes_moved).to_string(),
                format!("{:.0}%", r.utilization() * 100.0),
            ]);
        }
    }
    print_table(
        "Ext-D: policies vs cluster size (16k events = 16 GB, parallel staging)",
        &["nodes", "policy", "makespan(s)", "raw moved", "util"],
        &rows,
    );

    // headline ratio: grid-brick vs central at 8 nodes
    let at8: Vec<&Vec<String>> =
        rows.iter().filter(|r| r[0] == "8").collect();
    let get = |name: &str| -> f64 {
        at8.iter()
            .find(|r| r[1] == name)
            .and_then(|r| r[2].parse().ok())
            .unwrap_or(f64::NAN)
    };
    println!(
        "\nheadline @8 nodes: grid-brick {:.0}s vs central {:.0}s -> {:.1}x",
        get("locality"),
        get("central"),
        get("central") / get("locality")
    );
}
