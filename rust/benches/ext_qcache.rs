//! Bench: **Ext-H** — the qcache repeated-analysis lever. A zipfian mix
//! of user queries (interactive-analysis traffic: the same and
//! near-same selections re-run constantly) flows through the live
//! cluster twice:
//!
//! - **cold**: the cache is flushed before every submission, so every
//!   job recomputes every brick — the pre-qcache cost of the sequence;
//! - **warm**: the cache is populated once per distinct selection, then
//!   the same sequence replays — repeated queries are served from the
//!   full-result cache at admission, dispatching zero tasks.
//!
//! Reported: jobs/sec and events/sec for both passes, the warm/cold
//! speedup, the warm full-hit rate, and the **bit-identity flag**
//! (every warm histogram must equal its cold counterpart bit for bit —
//! a cache that changes physics is worse than no cache). Results land
//! in `BENCH_qcache.json` at the repo root; CI runs this in smoke mode
//! (`GEPS_BENCH_SMOKE=1`), uploads the JSON, and gates on bit-identity
//! plus warm-throughput >= cold-throughput.
//!
//! Hermetic: kernels run on the backend `GEPS_BACKEND` selects (the
//! pure-Rust reference programs by default).

use geps::cluster::ClusterHandle;
use geps::config::ClusterConfig;
use geps::util::bench::print_table;
use geps::util::Rng;
use std::time::{Duration, Instant};

/// Distinct user selections (the "catalog" of saved analyses users
/// keep re-running).
const FILTERS: [&str; 8] = [
    "max_pair_mass > 80 && max_pair_mass < 100",
    "met > 10",
    "n_tracks >= 8",
    "sum_pt > 50 || max_pt > 25",
    "ht_frac < 0.5 && max_abs_eta < 2.5",
    "max_pt > 20 && met > 5",
    "total_mass > 100",
    "abs(max_abs_eta - 2.0) < 1.5",
];

/// Zipf(s) rank sampler over `n` items: p(k) ~ 1/(k+1)^s.
fn zipf(rng: &mut Rng, n: usize, s: f64) -> usize {
    let weights: Vec<f64> =
        (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).collect();
    let total: f64 = weights.iter().sum();
    let mut x = rng.f64() * total;
    for (k, w) in weights.iter().enumerate() {
        x -= w;
        if x <= 0.0 {
            return k;
        }
    }
    n - 1
}

fn run_query(cluster: &ClusterHandle, filter: &str) -> Vec<u32> {
    let job = cluster
        .try_submit(filter, "locality")
        .expect("bench filters are valid");
    let status = cluster
        .wait(job, Duration::from_secs(300))
        .expect("job reaches a terminal state");
    assert_eq!(
        status,
        geps::catalog::JobStatus::Done,
        "query '{filter}' failed"
    );
    // the catalogue flips DONE an instant before the broker publishes
    // the merged histogram; poll the tiny window out
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if let Some(h) = cluster.histogram(job) {
            return h.iter().map(|v| v.to_bits()).collect();
        }
        assert!(Instant::now() < deadline, "histogram never published");
        std::thread::sleep(Duration::from_millis(2));
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("GEPS_BENCH_SMOKE").is_ok();
    let (n_events, n_queries) = if smoke { (600, 10) } else { (2000, 30) };
    let zipf_s = 1.1;

    let mut cfg = ClusterConfig::default();
    cfg.n_events = n_events;
    cfg.events_per_brick = 250;
    cfg.time_scale = 5000.0;
    cfg.max_concurrent_jobs = 4;
    cfg.qcache_enabled = true;
    let n_bricks = n_events.div_ceil(cfg.events_per_brick);
    let cluster = ClusterHandle::start(
        cfg,
        geps::runtime::default_artifacts_dir(),
    )?;

    // the zipfian request sequence, fixed across both passes
    let mut rng = Rng::new(0x9CAC4E);
    let seq: Vec<usize> =
        (0..n_queries).map(|_| zipf(&mut rng, FILTERS.len(), zipf_s)).collect();
    let distinct: usize = {
        let mut seen = [false; FILTERS.len()];
        for &k in &seq {
            seen[k] = true;
        }
        seen.iter().filter(|s| **s).count()
    };

    // ---- cold pass: flush before every job => full recompute -------
    let mut cold_hists = Vec::with_capacity(seq.len());
    let t0 = Instant::now();
    for &k in &seq {
        cluster.cache_flush();
        cold_hists.push(run_query(&cluster, FILTERS[k]));
    }
    let cold_wall = t0.elapsed().as_secs_f64();

    // ---- warm pass: populate once per distinct selection, replay ---
    cluster.cache_flush();
    for (k, filter) in FILTERS.iter().enumerate() {
        if seq.contains(&k) {
            run_query(&cluster, filter);
        }
    }
    let hits_before = cluster.metrics.counter("qcache.hits_full").get();
    let mut warm_hists = Vec::with_capacity(seq.len());
    let t1 = Instant::now();
    for &k in &seq {
        warm_hists.push(run_query(&cluster, FILTERS[k]));
    }
    let warm_wall = t1.elapsed().as_secs_f64();
    let warm_hits =
        cluster.metrics.counter("qcache.hits_full").get() - hits_before;
    let hit_rate = warm_hits as f64 / seq.len() as f64;

    let bit_identical = cold_hists == warm_hists;
    let stats = cluster.cache_stats();
    cluster.shutdown();

    let jobs_per_sec = |wall: f64| seq.len() as f64 / wall.max(1e-9);
    let events_per_sec =
        |wall: f64| (seq.len() * n_events) as f64 / wall.max(1e-9);
    let speedup = cold_wall / warm_wall.max(1e-9);

    print_table(
        "Ext-H qcache: zipfian repeated-analysis mix",
        &["pass", "wall", "jobs/s", "events/s"],
        &[
            vec![
                "cold (flushed)".into(),
                format!("{cold_wall:.2} s"),
                format!("{:.1}", jobs_per_sec(cold_wall)),
                format!("{:.0}", events_per_sec(cold_wall)),
            ],
            vec![
                "warm (cached)".into(),
                format!("{warm_wall:.2} s"),
                format!("{:.1}", jobs_per_sec(warm_wall)),
                format!("{:.0}", events_per_sec(warm_wall)),
            ],
        ],
    );
    println!(
        "\nspeedup {speedup:.1}x, warm full-hit rate {:.0}% \
         ({warm_hits}/{} queries, {distinct} distinct), bit-identical: \
         {bit_identical}",
        hit_rate * 100.0,
        seq.len(),
    );

    let doc = geps::util::json::Json::obj()
        .set("bench", "qcache")
        .set("generated", true)
        .set("smoke", smoke)
        .set(
            "config",
            geps::util::json::Json::obj()
                .set("n_events", n_events)
                .set("bricks", n_bricks)
                .set("queries", seq.len())
                .set("distinct_filters", distinct)
                .set("zipf_s", zipf_s),
        )
        .set(
            "cold",
            geps::util::json::Json::obj()
                .set("wall_s", cold_wall)
                .set("jobs_per_sec", jobs_per_sec(cold_wall))
                .set("events_per_sec", events_per_sec(cold_wall)),
        )
        .set(
            "warm",
            geps::util::json::Json::obj()
                .set("wall_s", warm_wall)
                .set("jobs_per_sec", jobs_per_sec(warm_wall))
                .set("events_per_sec", events_per_sec(warm_wall)),
        )
        .set("speedup_warm_over_cold", speedup)
        .set("hit_rate_full", hit_rate)
        .set("cache_bytes", stats.bytes)
        .set("bit_identical", bit_identical);

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_qcache.json");
    std::fs::write(&path, format!("{doc}\n"))?;
    println!("wrote {}", path.display());
    Ok(())
}
