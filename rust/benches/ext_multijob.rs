//! Bench: **Ext-F** — the concurrent JSE throughput lever. A fixed batch
//! of 8 mixed-filter jobs flows through the live cluster at
//! `max_concurrent_jobs` = 1 (the 2003 sequential broker), 2, 4 and 8;
//! we report batch wall-clock, jobs/sec and the node-idle fraction
//! (1 - task-busy slot-time / total slot-time). The sequential broker
//! strands node slots whenever a job's tail tasks drain; the shared
//! event loop hands those slots to the next job immediately, so
//! jobs/sec should rise (and idle fraction fall) with depth.
//! Requires `make artifacts`.

use geps::cluster::ClusterHandle;
use geps::config::ClusterConfig;
use geps::util::bench::print_table;
use geps::util::json::Json;
use std::time::{Duration, Instant};

const JOBS: usize = 8;

const FILTERS: [&str; 5] = [
    "max_pair_mass > 80 && max_pair_mass < 100",
    "met > 10",
    "n_tracks >= 8",
    "sum_pt > 50 || max_pt > 25",
    "ht_frac < 0.5 && max_abs_eta < 2.5",
];

fn main() -> anyhow::Result<()> {
    // every node executor in this bench runs this many pipelines per
    // task (the `[node] pipelines` knob at its auto default)
    let pipelines = ClusterConfig::default().effective_pipelines();
    let mut rows = Vec::new();
    let mut walls = Vec::new();
    let mut depths = Vec::new();
    for max_jobs in [1usize, 2, 4, 8] {
        let mut cfg = ClusterConfig::default();
        cfg.n_events = 512;
        cfg.events_per_brick = 64;
        cfg.replication = 2; // survive even a (jitter-induced) node loss
        cfg.time_scale = 5000.0;
        cfg.max_concurrent_jobs = max_jobs;
        // the 8-job batch cycles 5 filters; qcache would serve the
        // repeats for free and skew the depth comparison (the cache
        // lever has its own bench, ext_qcache)
        cfg.qcache_enabled = false;
        let slots_total: usize = cfg.nodes.iter().map(|n| n.slots).sum();
        let cluster = ClusterHandle::start(
            cfg,
            geps::runtime::default_artifacts_dir(),
        )?;

        let t0 = Instant::now();
        let jobs: Vec<u64> = (0..JOBS)
            .map(|i| {
                cluster.submit(FILTERS[i % FILTERS.len()], "locality")
            })
            .collect();
        for job in &jobs {
            cluster.wait(*job, Duration::from_secs(300))?;
        }
        let wall = t0.elapsed().as_secs_f64();

        // node-idle fraction from the coordinator's task-busy histogram:
        // sum of per-task dispatch-to-completion times vs. wall * total
        // slots (exact busy time here: the default nodes run slots = 1,
        // so at most one task is ever outstanding per node)
        let busy = cluster.metrics.histogram("jse.task_busy_ns");
        let busy_s = busy.mean() * busy.count() as f64 / 1e9;
        let idle_frac =
            (1.0 - busy_s / (wall * slots_total as f64)).clamp(0.0, 1.0);

        // sanity: every job processed the full dataset
        {
            let cat = cluster.catalog.lock().unwrap();
            for job in &jobs {
                let j = cat.jobs.get(*job).unwrap();
                assert_eq!(
                    j.events_processed, 512,
                    "job {job} incomplete: {j:?}"
                );
            }
        }
        cluster.shutdown();

        rows.push(vec![
            max_jobs.to_string(),
            format!("{wall:.2}"),
            format!("{:.2}", JOBS as f64 / wall),
            format!("{:.1}%", idle_frac * 100.0),
        ]);
        walls.push(wall);
        depths.push(
            Json::obj()
                .set("max_concurrent_jobs", max_jobs)
                .set("wall_s", wall)
                .set("jobs_per_sec", JOBS as f64 / wall)
                .set("node_idle_frac", idle_frac),
        );
    }
    print_table(
        "Ext-F: 8-job batch vs JSE concurrency (512-event jobs, mixed filters)",
        &["max_concurrent_jobs", "wall(s)", "jobs/s", "node idle"],
        &rows,
    );
    // the acceptance bar: concurrency >= 4 beats the sequential broker
    assert!(
        walls[2] < walls[0],
        "concurrent (4) wall {:.2}s not below sequential wall {:.2}s",
        walls[2],
        walls[0]
    );
    println!(
        "speedup at depth 4: {:.2}x over the sequential broker",
        walls[0] / walls[2]
    );

    let doc = Json::obj()
        .set("bench", "ext_multijob")
        .set("generated", true)
        .set("jobs", JOBS)
        .set("node_pipelines", pipelines)
        .set("depths", Json::Arr(depths))
        .set("speedup_depth4_over_sequential", walls[0] / walls[2]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_ext_multijob.json");
    std::fs::write(&path, format!("{doc}\n"))?;
    println!("wrote {}", path.display());
    Ok(())
}
