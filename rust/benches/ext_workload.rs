//! Bench: **Ext-E** — sustained multi-job workload on the LIVE cluster
//! (real threads, real PJRT compute, real byte movement): the paper's
//! §6 protocol ran 130 executions; here a queue of jobs with mixed
//! filters flows through the portal-facing API and we report job
//! latency (queue + run) and JSE throughput. Requires `make artifacts`.
//!
//! This is the "framework a team would deploy" check: the sequential
//! 2003-style broker serializes jobs, so p99 latency grows linearly
//! with queue depth — measured here by pinning `max_concurrent_jobs`
//! to 1. The concurrent event-loop JSE that lifts this is measured by
//! the companion `ext_multijob` bench.

use geps::cluster::ClusterHandle;
use geps::config::ClusterConfig;
use geps::util::bench::print_table;
use geps::util::json::Json;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let mut cfg = ClusterConfig::default();
    cfg.n_events = 512;
    cfg.events_per_brick = 128;
    cfg.replication = 2; // survive even a (jitter-induced) node loss
    cfg.time_scale = 5000.0;
    cfg.max_concurrent_jobs = 1; // the 2003 sequential broker, measured
    // repeated filters must really recompute: this measures broker
    // latency, not cache hits (qcache has its own bench, ext_qcache)
    cfg.qcache_enabled = false;
    // every node executor runs this many pipelines per task (the
    // `[node] pipelines` knob at its auto default)
    let pipelines = cfg.effective_pipelines();
    let cluster =
        ClusterHandle::start(cfg, geps::runtime::default_artifacts_dir())?;

    let filters = [
        "max_pair_mass > 80 && max_pair_mass < 100",
        "met > 10",
        "n_tracks >= 8",
        "sum_pt > 50 || max_pt > 25",
        "ht_frac < 0.5 && max_abs_eta < 2.5",
    ];

    let mut rows = Vec::new();
    let mut depths = Vec::new();
    for depth in [1usize, 4, 8, 16] {
        let t0 = Instant::now();
        let jobs: Vec<(u64, Instant)> = (0..depth)
            .map(|i| {
                (
                    cluster.submit(filters[i % filters.len()], "locality"),
                    Instant::now(),
                )
            })
            .collect();
        let mut latencies: Vec<f64> = Vec::new();
        for (job, submitted) in &jobs {
            cluster.wait(*job, Duration::from_secs(300))?;
            latencies.push(submitted.elapsed().as_secs_f64());
        }
        let wall = t0.elapsed().as_secs_f64();
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p = |q: f64| {
            latencies[((latencies.len() - 1) as f64 * q) as usize]
        };
        rows.push(vec![
            depth.to_string(),
            format!("{:.2}", wall),
            format!("{:.1}", depth as f64 / wall),
            format!("{:.2}", p(0.5)),
            format!("{:.2}", p(0.99)),
        ]);
        depths.push(
            Json::obj()
                .set("queue_depth", depth)
                .set("wall_s", wall)
                .set("jobs_per_sec", depth as f64 / wall)
                .set("p50_latency_s", p(0.5))
                .set("p99_latency_s", p(0.99)),
        );
    }
    print_table(
        "Ext-E: live cluster, 512-event jobs, mixed filters (sequential 2003 broker)",
        &["queue depth", "wall(s)", "jobs/s", "p50 latency(s)", "p99 latency(s)"],
        &rows,
    );

    // sanity: every job processed the full dataset
    let cat = cluster.catalog.lock().unwrap();
    for (id, j) in cat.jobs.iter() {
        assert_eq!(
            j.events_processed, 512,
            "job {id} incomplete: {j:?}"
        );
    }
    drop(cat);
    cluster.shutdown();

    let doc = Json::obj()
        .set("bench", "ext_workload")
        .set("generated", true)
        .set("node_pipelines", pipelines)
        .set("depths", Json::Arr(depths));
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_ext_workload.json");
    std::fs::write(&path, format!("{doc}\n"))?;
    println!("wrote {}", path.display());
    Ok(())
}
