//! Bench: **Ext-B** — §7 "error handling and fault-tolerance / recover
//! mechanisms / redundancy": kill a node mid-job and measure completion,
//! makespan inflation, and data loss across replication factors and
//! policies.
//!
//! Shape targets: RF=1 loses the dead node's sole-held bricks (the
//! paper's "biggest disadvantage"); RF>=2 completes everything with a
//! modest makespan penalty; PROOF-style packet reprocessing loses
//! nothing that is still readable and re-spreads the dead node's packets.

use geps::netsim::{Link, Topology};
use geps::scheduler::Policy;
use geps::sim::{FailureSpec, Scenario, ScenarioConfig};
use geps::util::bench::print_table;

fn run(policy: Policy, rf: usize, kill_at_frac: f64) -> Vec<String> {
    let mut cfg = ScenarioConfig::paper_defaults(
        Topology::lan_cluster(4, Link::lan_fast_ethernet()),
        policy,
        4000,
    );
    cfg.events_per_brick = 250;
    cfg.replication = rf;
    cfg.raw_at_leader = false;

    // healthy baseline for the makespan penalty
    let healthy = Scenario::run(cfg.clone());

    cfg.failures = vec![FailureSpec {
        node: "node1".into(),
        at_s: healthy.makespan_s * kill_at_frac,
    }];
    let r = Scenario::run(cfg);
    vec![
        policy.name().to_string(),
        rf.to_string(),
        format!("{:.0}", healthy.makespan_s),
        format!("{:.0}", r.makespan_s),
        format!("{:+.0}%", (r.makespan_s / healthy.makespan_s - 1.0) * 100.0),
        format!("{}/{}", r.events_processed, 4000),
        r.lost_bricks.to_string(),
        if r.completed { "yes" } else { "NO" }.to_string(),
    ]
}

fn main() {
    let mut rows = Vec::new();
    for policy in [Policy::Locality, Policy::Proof, Policy::Gfarm] {
        for rf in [1usize, 2, 3] {
            rows.push(run(policy, rf, 0.5));
        }
    }
    print_table(
        "Ext-B: node killed at 50% of healthy makespan (4 nodes, 4000 events)",
        &[
            "policy",
            "RF",
            "healthy(s)",
            "with-failure(s)",
            "penalty",
            "events",
            "lost bricks",
            "done",
        ],
        &rows,
    );

    // kill-time sweep at RF=2, locality
    let mut rows = Vec::new();
    for frac in [0.1f64, 0.25, 0.5, 0.75, 0.9] {
        let mut r = run(Policy::Locality, 2, frac);
        r.remove(0);
        r.remove(0);
        r.insert(0, format!("{:.0}%", frac * 100.0));
        rows.push(r);
    }
    print_table(
        "Ext-B: kill-time sweep (locality, RF=2)",
        &[
            "killed at",
            "healthy(s)",
            "with-failure(s)",
            "penalty",
            "events",
            "lost bricks",
            "done",
        ],
        &rows,
    );
}
