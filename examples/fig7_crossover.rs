//! Fig 7 reproduction driver — the paper's headline experiment.
//!
//! Sweeps raw-event-file size (number of ~1 MB events) and compares
//! "running only on hobbit" against "running in parallel between gandalf
//! and hobbit" (paper §6), using the discrete-event simulator whose
//! compute rate is calibrated against the real measured PJRT kernel
//! (EXPERIMENTS.md §Calibration). Repeats each point `--reps` times
//! mirroring the paper's 130-execution protocol (13 groups × 10).
//!
//! Expected shape (paper): single node wins below the ~2000-event
//! watershed; GEPS parallel wins above it, with modest (~1.2-1.4×) gains.
//!
//! Run: `cargo run --release --example fig7_crossover -- --reps 10`

use geps::sim::{Scenario, ScenarioConfig};
use geps::util::bench::print_table;

fn main() {
    let reps: usize = std::env::args()
        .skip_while(|a| a != "--reps")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);

    let groups = [
        250usize, 500, 750, 1000, 1500, 2000, 2500, 3000, 4000, 6000, 8000,
        12000, 16000,
    ]; // 13 groups, as in §6

    let mut rows = Vec::new();
    let mut crossover: Option<usize> = None;
    let mut prev_winner_single = true;
    for &n in &groups {
        let mut single = 0.0;
        let mut geps = 0.0;
        for _ in 0..reps {
            single +=
                Scenario::run(ScenarioConfig::fig7_hobbit_only(n)).makespan_s;
            geps += Scenario::run(ScenarioConfig::fig7_geps(n)).makespan_s;
        }
        single /= reps as f64;
        geps /= reps as f64;
        let winner = if geps < single { "GEPS" } else { "hobbit" };
        if prev_winner_single && geps < single && crossover.is_none() {
            crossover = Some(n);
        }
        prev_winner_single = geps >= single;
        rows.push(vec![
            n.to_string(),
            format!("{single:.1}"),
            format!("{geps:.1}"),
            format!("{:.2}x", single / geps),
            winner.to_string(),
        ]);
    }
    print_table(
        &format!(
            "Fig 7: time cost (s) vs raw event file size ({} runs/point = {} executions)",
            reps,
            groups.len() * reps * 2
        ),
        &["events", "hobbit-only(s)", "GEPS(s)", "speedup", "winner"],
        &rows,
    );
    match crossover {
        Some(n) => println!(
            "\ncrossover (watershed): between {} and {} events — paper reports ~2000",
            groups[groups.iter().position(|g| *g == n).unwrap() - 1],
            n
        ),
        None => println!("\nno crossover observed (unexpected)"),
    }

    // ablation the paper discusses in §6: granularity — smaller bricks
    // mean more per-task overhead and more transfer setup
    let mut rows = Vec::new();
    for epb in [50usize, 125, 250, 500, 1000, 2000] {
        let mut cfg = ScenarioConfig::fig7_geps_staged(4000);
        cfg.events_per_brick = epb;
        let r = Scenario::run(cfg);
        rows.push(vec![
            epb.to_string(),
            format!("{}", 4000usize.div_ceil(epb)),
            format!("{:.1}", r.makespan_s),
        ]);
    }
    print_table(
        "granularity ablation (§6): 4000 events, prototype (staged) mode",
        &["events/brick", "bricks", "makespan(s)"],
        &rows,
    );
}
