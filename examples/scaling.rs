//! Scaling study: grid-brick vs the traditional central-server pattern
//! as the cluster grows (the paper's core scalability claim, §4:
//! "scalability ... is just a matter of adding more Grid nodes").
//!
//! Uses the calibrated DES so cluster sizes up to 32 nodes sweep in
//! milliseconds; prints makespan, leader-NIC bytes, and utilisation per
//! policy × cluster size. Expected shape: locality scales with node
//! count until the serialized JSE staging dominates, while central
//! flattens early on the leader's NIC — and the paper's §7 "load
//! balancing" policy recovers most of locality's loss on heterogeneous
//! clusters.
//!
//! Run: `cargo run --release --example scaling`

use geps::netsim::{Link, Topology};
use geps::scheduler::Policy;
use geps::sim::{Scenario, ScenarioConfig};
use geps::util::bench::print_table;
use geps::util::ByteSize;

fn main() {
    // homogeneous scaling
    let mut rows = Vec::new();
    for &nodes in &[1usize, 2, 4, 8, 16, 32] {
        for (policy, par_stage) in [
            (Policy::Locality, false),
            (Policy::Locality, true),
            (Policy::Central, false),
        ] {
            let mut cfg = ScenarioConfig::paper_defaults(
                Topology::lan_cluster(nodes, Link::lan_fast_ethernet()),
                policy,
                16_000,
            );
            cfg.events_per_brick = 500;
            cfg.raw_at_leader = false; // grid-brick placement
            cfg.stage_parallel = par_stage; // §7 extension toggle
            let r = Scenario::run(cfg);
            let name = if par_stage {
                format!("{}+par-stage", policy.name())
            } else {
                policy.name().to_string()
            };
            rows.push(vec![
                nodes.to_string(),
                name,
                format!("{:.0}", r.makespan_s),
                ByteSize(r.raw_bytes_moved).to_string(),
                format!("{:.0}%", r.utilization() * 100.0),
            ]);
        }
    }
    print_table(
        "scaling: 16k events (16 GB), fast Ethernet",
        &["nodes", "policy", "makespan(s)", "raw moved", "util"],
        &rows,
    );

    // heterogeneous cluster: the paper's §7 "submit more work to the
    // best nodes"
    let mut rows = Vec::new();
    for policy in [Policy::Locality, Policy::Balanced, Policy::Proof] {
        let mut cfg = ScenarioConfig::paper_defaults(
            Topology::lan_cluster(8, Link::lan_fast_ethernet()),
            policy,
            16_000,
        );
        cfg.events_per_brick = 500;
        cfg.raw_at_leader = false;
        for (i, speed) in
            [1.0, 1.0, 0.5, 0.5, 0.25, 0.25, 2.0, 2.0].iter().enumerate()
        {
            cfg.speeds.insert(format!("node{i}"), *speed);
        }
        let r = Scenario::run(cfg);
        rows.push(vec![
            policy.name().to_string(),
            format!("{:.0}", r.makespan_s),
            ByteSize(r.raw_bytes_moved).to_string(),
            format!("{:.0}%", r.utilization() * 100.0),
        ]);
    }
    print_table(
        "heterogeneous 8-node cluster (speeds 0.25-2.0), 16k events",
        &["policy", "makespan(s)", "raw moved", "util"],
        &rows,
    );
}
