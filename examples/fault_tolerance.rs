//! Fault tolerance demo — the paper's §7 future work, live.
//!
//! Runs a real (not simulated) cluster with replication factor 2, kills
//! a node mid-job, and shows the job still completing with every event
//! processed exactly once: the heartbeat monitor detects the death, the
//! locality scheduler fails the node's bricks over to surviving replica
//! holders, and the merge is complete.
//!
//! Then re-runs with replication factor 1 to demonstrate the paper's
//! "biggest disadvantage": without replicas, a dead node's data is lost.
//!
//! Run: `make artifacts && cargo run --release --example fault_tolerance`

use geps::catalog::JobStatus;
use geps::cluster::ClusterHandle;
use geps::config::{ClusterConfig, NodeSpec};
use std::time::Duration;

fn cluster_config(replication: usize) -> ClusterConfig {
    let mut cfg = ClusterConfig::default();
    cfg.nodes = vec![
        NodeSpec { name: "node0".into(), speed: 1.0, slots: 1 },
        NodeSpec { name: "node1".into(), speed: 1.0, slots: 1 },
        NodeSpec { name: "node2".into(), speed: 1.0, slots: 1 },
    ];
    cfg.n_events = 3000;
    cfg.events_per_brick = 125; // 24 bricks over 3 nodes
    cfg.replication = replication;
    // slow the virtual network a touch so the job is still running when
    // we pull the trigger
    cfg.time_scale = 100.0;
    cfg
}

fn run_with_kill(replication: usize) -> anyhow::Result<(JobStatus, u64, u64)> {
    let cluster = ClusterHandle::start(
        cluster_config(replication),
        geps::runtime::default_artifacts_dir(),
    )?;
    let job = cluster.submit("n_tracks >= 2", "locality");

    // let the job get going, then kill a node mid-flight
    std::thread::sleep(Duration::from_millis(40));
    assert!(cluster.kill_node("node1"));
    println!("[ft] node1 killed mid-job (replication={replication})");

    let status = cluster.wait(job, Duration::from_secs(180))?;
    let (processed, selected) = {
        let cat = cluster.catalog.lock().unwrap();
        let j = cat.jobs.get(job).unwrap();
        (j.events_processed, j.events_selected)
    };
    cluster.shutdown();
    Ok((status, processed, selected))
}

fn main() -> anyhow::Result<()> {
    // RF=2: must survive
    let (status, processed, _) = run_with_kill(2)?;
    println!(
        "[ft] replication=2: job {status:?}, {processed}/3000 events processed"
    );
    assert_eq!(status, JobStatus::Done);
    assert_eq!(processed, 3000, "failover must lose nothing");

    // RF=1: the paper's known weakness — data on the dead node is gone.
    // The job still terminates (reporting the loss) instead of hanging.
    let (status, processed, _) = run_with_kill(1)?;
    println!(
        "[ft] replication=1: job {status:?}, {processed}/3000 events processed"
    );
    if processed < 3000 {
        println!(
            "[ft] {} events LOST with the dead node — the paper's \"biggest disadvantage\"",
            3000 - processed
        );
    }
    assert!(
        processed <= 3000,
        "without replicas some bricks may be lost"
    );
    println!("fault_tolerance OK");
    Ok(())
}
