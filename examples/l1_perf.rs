//! L1/L2 performance probe (§Perf): measures PJRT throughput of the
//! Pallas-lowered features program against the pure-jnp reference
//! lowering, sweeps the Pallas block size (AOT variants built with
//! `python -m compile.aot --out-dir ../artifacts/perf --block-sweep`),
//! and prints the static VMEM-footprint estimate per block size that
//! DESIGN.md §Hardware-Adaptation calls for.
//!
//! Run:
//!   cd python && python -m compile.aot --out-dir ../artifacts/perf --block-sweep && cd ..
//!   GEPS_ARTIFACTS=artifacts/perf cargo run --release --example l1_perf

use geps::events::{EventBatch, EventGenerator, GeneratorConfig};
use geps::runtime::Engine;
use geps::util::bench::{bench, print_table};

fn vmem_estimate(block_b: usize, t: usize) -> f64 {
    // per-block VMEM residency (f32 bytes): tracks in (B,T,4), mask (B,T),
    // calibrated copy (B,T,4), pairwise m2 + validity (B,T,T)*2,
    // per-track temporaries ~6x(B,T), out (B,F)
    let f = 4.0;
    let b = block_b as f64;
    let t = t as f64;
    (b * t * 4.0 * 2.0 + b * t + b * t * t * 2.0 + 6.0 * b * t + b * 8.0) * f
}

fn main() -> anyhow::Result<()> {
    let dir = geps::runtime::default_artifacts_dir();
    let engine = Engine::load(&dir)?;
    let (bsz, t) = (engine.manifest.batch, engine.manifest.max_tracks);
    let events = EventGenerator::new(GeneratorConfig::default(), 5).take(bsz);
    let batch = EventBatch::pack(&events, bsz, t);
    let calib = Engine::identity_calib();

    let mut names: Vec<String> = engine
        .manifest
        .programs
        .keys()
        .filter(|n| n.starts_with("features"))
        .cloned()
        .collect();
    names.sort_by_key(|n| {
        n.strip_prefix("features_b")
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(if n == "features" { 32 } else { 0 })
    });

    let mut rows = Vec::new();
    for name in &names {
        let s = bench(3, 30, || {
            std::hint::black_box(
                engine.features_variant(name, &batch, &calib).unwrap(),
            );
        });
        let block = name
            .strip_prefix("features_b")
            .and_then(|v| v.parse::<usize>().ok());
        let vmem = block
            .map(|b| format!("{:.2} MiB", vmem_estimate(b, t) / (1 << 20) as f64))
            .unwrap_or_else(|| "-".into());
        rows.push(vec![
            name.clone(),
            format!("{:.2} ms", s.mean_ns / 1e6),
            format!("{:.0}", s.throughput(bsz as f64)),
            vmem,
        ]);
    }
    print_table(
        "L1 features program: PJRT CPU throughput per lowering variant",
        &["program", "mean/batch", "events/s", "est. VMEM/block"],
        &rows,
    );
    println!(
        "\nNote: interpret=True lowers Pallas to plain HLO; CPU timings gauge\n\
         the lowered graph's quality, not TPU wallclock. The VMEM column is\n\
         the static footprint that must stay under ~16 MiB/core on a real TPU."
    );
    Ok(())
}
