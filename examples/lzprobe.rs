//! LZSS codec profiler (dev tool for the §Perf loop): measures compress
//! and decompress rates plus the achieved ratio on a realistic raw event
//! payload. Real event payloads are float-heavy and essentially
//! incompressible (ratio ~1.04); the brick format's per-page store-raw
//! fallback makes that cheap, and this probe keeps the number honest.
use geps::brick::{codec, BrickFile, BrickId, Codec};
use geps::events::{EventGenerator, GeneratorConfig};
fn main() {
    let events = EventGenerator::new(GeneratorConfig::default(), 7).take(2000);
    let brick = BrickFile::encode(BrickId::new(1,0), &events, Codec::Raw, 2000);
    let p = &brick.bytes;
    let t = std::time::Instant::now();
    let mut c = Vec::new();
    for _ in 0..50 { c = codec::compress(p); }
    let dt = t.elapsed().as_secs_f64()/50.0;
    println!("payload {} B -> {} B (ratio {:.3}), compress {:.1} MB/s",
        p.len(), c.len(), c.len() as f64/p.len() as f64, p.len() as f64/dt/1e6);
    let t = std::time::Instant::now();
    for _ in 0..50 { codec::decompress(&c, p.len()).unwrap(); }
    let dt = t.elapsed().as_secs_f64()/50.0;
    println!("decompress {:.1} MB/s", p.len() as f64/dt/1e6);
}
