//! Quickstart: the 60-second GEPS tour.
//!
//! Starts a live two-node cluster (the paper's gandalf+hobbit testbed),
//! submits a physics filter through the same API the portal uses, waits
//! for the JSE to schedule/execute/merge it, and prints the result —
//! all three layers running for real (rust coordinator, AOT'd JAX
//! pipeline, Pallas kernel under PJRT).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use geps::config::ClusterConfig;
use geps::cluster::ClusterHandle;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // 1. a cluster config — defaults are the paper's testbed
    let mut config = ClusterConfig::default();
    config.n_events = 1000;
    config.events_per_brick = 125;
    config.replication = 2;

    // 2. start: generates events, splits them into bricks on the nodes'
    //    disks, compiles the AOT artifacts, spawns node actors + JSE
    let cluster =
        ClusterHandle::start(config, geps::runtime::default_artifacts_dir())?;

    // 3. ask GRIS what resources exist (the portal's node-info page)
    for (dn, attrs) in
        cluster.gris_search("o=geps", "(objectclass=GridComputeResource)")?
    {
        println!(
            "node {dn}: {} brick(s), speed {}",
            attrs.get("nbricks").map(String::as_str).unwrap_or("?"),
            attrs.get("speed").map(String::as_str).unwrap_or("?"),
        );
    }

    // 4. submit a Z-boson-ish selection, exactly what a user would type
    //    into the Fig 4 submit form
    let job = cluster.submit(
        "max_pair_mass > 80 && max_pair_mass < 100 && max_pt > 20",
        "locality",
    );
    let status = cluster.wait(job, Duration::from_secs(120))?;

    // 5. read back the merged result
    let (processed, selected) = {
        let cat = cluster.catalog.lock().unwrap();
        let j = cat.jobs.get(job).unwrap();
        (j.events_processed, j.events_selected)
    };
    println!("job {job}: {status:?} — selected {selected} of {processed} events");
    assert_eq!(processed, 1000);
    assert!(selected > 0, "the Z peak should select something");

    // 6. the merged max_pair_mass histogram peaks at the resonance
    let hist = cluster.histogram(job).expect("histogram");
    let bins = hist.len() / geps::events::NUM_FEATURES;
    let mass = &hist[5 * bins..6 * bins];
    let (peak_bin, _) = mass
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    let (lo, hi) = geps::events::FeatureId::MaxPairMass.hist_range();
    let w = (hi - lo) / bins as f32;
    let peak_mass = lo + (peak_bin as f32 + 0.5) * w;
    println!("selected-mass peak at ~{peak_mass:.0} GeV (expect ~91)");
    assert!((peak_mass - 91.2).abs() < 10.0);

    cluster.shutdown();
    println!("quickstart OK");
    Ok(())
}
